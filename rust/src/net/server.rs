//! LCQ-RPC serving front end: the event-driven connection plane feeding
//! the in-process micro-batch server.
//!
//! Layout (drawn out in `docs/ARCHITECTURE.md`): the shared
//! [`plane`](crate::net::plane) runs one non-blocking acceptor plus
//! `net_threads` epoll readiness loops ([`crate::util::epoll`]), each
//! multiplexing its share of up to `max_connections` sockets — no
//! thread-per-connection, so thousands of mostly-idle connections cost
//! file descriptors, not stacks. This module is the plane's *dispatcher*:
//!
//! * decoded requests are validated against the registry, claimed
//!   against a **bounded in-flight budget** (`NetConfig::inflight_budget`,
//!   counted in rows, mirrored in the `net_inflight` gauge) and submitted
//!   to the shared [`MicroBatchServer`] via completion callbacks
//!   ([`Client::submit_with`]) — the net threads never block on compute;
//! * single-row requests hand the frame-decoded `Vec<f32>` straight to
//!   the engine (no per-request input copy); multi-row requests split
//!   into per-row jobs that coalesce back into engine batches;
//! * finished requests post encoded reply bytes back to the owning net
//!   thread, which queues them on the connection's **bounded write
//!   queue**; a connection trying to hold more than
//!   `NetConfig::max_inflight` requests-plus-queued-replies is shed typed
//!   [`ErrorCode::Overloaded`] (counted in `writeq_sheds`) — explicit
//!   backpressure at both the row and the connection scope.
//!
//! Every answered request leaves a [`Trace`](crate::obs::Trace) — accept →
//! decode → queue wait → batch assembly → pool compute → frame → write —
//! in a bounded overwrite-oldest ring, and every counter bump mirrors into
//! the process-wide [`obs`] registry. The whole picture (per-server
//! counters + batch-plane stats + pool profile + slowest traces) is served
//! over the wire as a v2 `Stats` frame and rendered by
//! [`NetServer::snapshot_json`]; the snapshot path reads shared atomics,
//! so it is valid at **every** lifecycle point — before the first request,
//! mid-epoll-loop, after [`NetServer::stop`], even after the batch server
//! is gone.
//!
//! [`NetServer::stop`] (also run on drop) stops the plane (open
//! connections get a best-effort `ShuttingDown` notice), then stops the
//! batch server; late executor callbacks complete into a disconnected
//! sink and are dropped harmlessly after releasing their budget rows.

use crate::net::plane::{
    self, Completion, CompletionSink, ConnKey, Dispatch, Plane, PlaneConfig, PlaneEvent,
    PlaneStats, RequestAction, RequestCtx, TraceDraft,
};
use crate::net::proto::{self, ErrorCode, Frame, HelloFrame, ModelEntry, RequestFrame};
use crate::obs::{self, CounterId, GaugeId, Trace, TraceRing};
use crate::serve::{
    Client, JobOutcome, MicroBatchServer, Registry, ServeStats, ServerConfig, StatsSnapshot,
};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Connection-plane knobs (config file: the `"net"` object **inside the
/// `"serve"` section** — the top-level `"net"` key names the MLP
/// architecture; see [`crate::config::NetSettings`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// Listen address, `host:port`. Port 0 binds an ephemeral port
    /// (report it with [`NetServer::local_addr`]) — the loopback tests and
    /// benches rely on this.
    pub bind_addr: String,
    /// Concurrent connections served across the net threads. Beyond
    /// this (plus a same-sized accept backlog), connections are shed with
    /// [`ErrorCode::Overloaded`] at handshake time.
    pub max_connections: usize,
    /// Net (event-loop) threads multiplexing the connections. Two
    /// suffice for thousands of sockets; compute happens elsewhere.
    pub net_threads: usize,
    /// Per-connection pipeline bound: requests in flight plus reply
    /// frames queued for write. A connection exceeding it is shed typed
    /// [`ErrorCode::Overloaded`] per excess request (the write-queue
    /// backpressure limit, counted in `writeq_sheds`).
    pub max_inflight: usize,
    /// In-flight request budget in **rows**: rows submitted to the batch
    /// server but not yet answered. Requests that would exceed it are
    /// shed with [`ErrorCode::Overloaded`] — the backpressure signal.
    pub inflight_budget: usize,
    /// Largest accepted frame payload, bytes (guards allocation).
    pub max_frame_bytes: usize,
    /// Recent-trace ring capacity (rounded up to a power of two). Each
    /// slot is ~80 bytes of atomics; the default keeps the last 256
    /// request traces.
    pub trace_slots: usize,
    /// Per-frame progress deadline: once the first byte of a request
    /// frame arrives, the whole frame must complete within this window or
    /// the connection is shed with [`ErrorCode::Timeout`] (slow-loris
    /// defense — the handshake deadline alone leaves the request loop
    /// holdable forever by dribbling one byte per poll tick). Idle
    /// connections (no partial frame) are unaffected.
    pub frame_deadline: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            bind_addr: "127.0.0.1:7070".to_string(),
            max_connections: 64,
            net_threads: 2,
            max_inflight: 8,
            inflight_budget: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME,
            trace_slots: 256,
            frame_deadline: Duration::from_secs(10),
        }
    }
}

/// Monotonic connection-plane counters (all-time, point-in-time read).
#[derive(Clone, Debug, Default)]
pub struct NetStatsSnapshot {
    /// Connections accepted by the listener.
    pub connections: u64,
    /// Connections shed at the door (slots + backlog full).
    pub connections_shed: u64,
    /// Requests answered with logits.
    pub requests_ok: u64,
    /// Requests shed by backpressure (the in-flight row budget or the
    /// per-connection pipeline bound).
    pub requests_shed: u64,
    /// Requests answered with a non-overload error.
    pub requests_failed: u64,
    /// Stats snapshot frames served.
    pub stats_requests: u64,
    /// Connections shed by the per-frame progress deadline (slow-loris).
    pub frame_timeouts: u64,
    /// Requests shed by the per-connection pipeline bound specifically
    /// (a subset of `requests_shed`).
    pub writeq_sheds: u64,
}

/// Per-server exact counters. Every bump also mirrors into the global
/// [`obs`] registry (when enabled), but the per-instance values are the
/// source of truth a test or a client can match against its own
/// accounting — many servers can coexist in one process without their
/// counts blending.
#[derive(Default)]
struct NetStats {
    connections: AtomicU64,
    connections_shed: AtomicU64,
    requests_ok: AtomicU64,
    requests_shed: AtomicU64,
    requests_failed: AtomicU64,
    stats_requests: AtomicU64,
    frame_timeouts: AtomicU64,
    writeq_sheds: AtomicU64,
}

impl NetStats {
    fn bump(own: &AtomicU64, id: CounterId) {
        own.fetch_add(1, Ordering::Relaxed);
        if obs::enabled() {
            obs::counter(id).inc();
        }
    }
    fn inc_connections(&self) {
        NetStats::bump(&self.connections, CounterId::NetConnections);
    }
    fn inc_connections_shed(&self) {
        NetStats::bump(&self.connections_shed, CounterId::NetConnectionsShed);
    }
    fn inc_ok(&self) {
        NetStats::bump(&self.requests_ok, CounterId::NetRequestsOk);
    }
    fn inc_shed(&self) {
        NetStats::bump(&self.requests_shed, CounterId::NetRequestsShed);
    }
    fn inc_failed(&self) {
        NetStats::bump(&self.requests_failed, CounterId::NetRequestsFailed);
    }
    fn inc_stats(&self) {
        NetStats::bump(&self.stats_requests, CounterId::NetStatsRequests);
    }
    fn inc_frame_timeout(&self) {
        NetStats::bump(&self.frame_timeouts, CounterId::NetFrameTimeouts);
    }
    fn inc_writeq_shed(&self) {
        NetStats::bump(&self.writeq_sheds, CounterId::NetWriteqSheds);
    }

    fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            connections_shed: self.connections_shed.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            requests_failed: self.requests_failed.load(Ordering::Relaxed),
            stats_requests: self.stats_requests.load(Ordering::Relaxed),
            frame_timeouts: self.frame_timeouts.load(Ordering::Relaxed),
            writeq_sheds: self.writeq_sheds.load(Ordering::Relaxed),
        }
    }

    fn to_json(&self) -> Json {
        let s = self.snapshot();
        Json::obj(vec![
            ("connections", Json::from(s.connections as usize)),
            ("connections_shed", Json::from(s.connections_shed as usize)),
            ("requests_ok", Json::from(s.requests_ok as usize)),
            ("requests_shed", Json::from(s.requests_shed as usize)),
            ("requests_failed", Json::from(s.requests_failed as usize)),
            ("stats_requests", Json::from(s.stats_requests as usize)),
            ("frame_timeouts", Json::from(s.frame_timeouts as usize)),
            ("writeq_sheds", Json::from(s.writeq_sheds as usize)),
        ])
    }
}

/// Everything the dispatcher needs, shared by `Arc` (the plane, the batch
/// executors' completion callbacks, and [`NetServer`] itself).
struct ConnCtx {
    registry: Arc<Registry>,
    client: Client,
    /// Rows currently submitted to the batch server and unanswered.
    inflight: AtomicUsize,
    inflight_max: usize,
    max_frame: usize,
    stats: NetStats,
    /// Batch-plane stats, shared with the micro-batch server's executors.
    /// Outlives the batch server itself, so snapshots are valid at every
    /// lifecycle point.
    serve_stats: Arc<ServeStats>,
    /// Recent request traces (overwrite-oldest; never blocks a net
    /// thread).
    traces: TraceRing,
    /// Per-net-thread plane books (wakeups, writeq depth), shared with
    /// the event plane.
    plane_stats: Arc<PlaneStats>,
    /// Precomputed server preamble + hello frame (catalog), written to
    /// every handshaken connection.
    hello: Vec<u8>,
}

impl ConnCtx {
    /// Return `n` rows to the in-flight budget (and publish the gauge).
    fn release_rows(&self, n: usize) {
        let prev = self.inflight.fetch_sub(n, Ordering::Relaxed);
        if obs::enabled() {
            obs::gauge(GaugeId::NetInflight).set(prev.saturating_sub(n) as f64);
        }
    }
}

/// The TCP serving front end: event plane + micro-batch server, one
/// self-contained unit (see module docs).
pub struct NetServer {
    ctx: Arc<ConnCtx>,
    local_addr: SocketAddr,
    plane: Option<Plane>,
    batch: Option<MicroBatchServer>,
}

impl NetServer {
    /// Bind `net_cfg.bind_addr`, start the micro-batch server with
    /// `serve_cfg`, and begin accepting LCQ-RPC connections on the event
    /// plane.
    pub fn start(
        registry: Arc<Registry>,
        serve_cfg: ServerConfig,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(&net_cfg.bind_addr)
            .with_context(|| format!("binding {}", net_cfg.bind_addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let batch = MicroBatchServer::start(Arc::clone(&registry), serve_cfg);
        let plane_stats = Arc::new(PlaneStats::new(net_cfg.net_threads.max(1)));
        let ctx = Arc::new(ConnCtx {
            hello: hello_bytes(&registry),
            client: batch.client(),
            serve_stats: batch.stats_handle(),
            registry,
            inflight: AtomicUsize::new(0),
            inflight_max: net_cfg.inflight_budget.max(1),
            max_frame: net_cfg.max_frame_bytes.max(1024),
            stats: NetStats::default(),
            traces: TraceRing::new(net_cfg.trace_slots.max(2)),
            plane_stats: Arc::clone(&plane_stats),
        });
        let plane_cfg = PlaneConfig {
            name: "lcq-net",
            max_connections: net_cfg.max_connections.max(1),
            net_threads: net_cfg.net_threads.max(1),
            max_inflight: net_cfg.max_inflight.max(1),
            max_frame: net_cfg.max_frame_bytes.max(1024),
            frame_deadline: net_cfg.frame_deadline.max(Duration::from_millis(25)),
            stats: plane_stats,
        };
        let dispatch: Arc<dyn Dispatch> = Arc::new(ServerDispatch { ctx: Arc::clone(&ctx) });
        let plane = match Plane::start(listener, dispatch, plane_cfg) {
            Ok(p) => p,
            Err(e) => {
                let mut batch = batch;
                batch.stop();
                return Err(e);
            }
        };
        Ok(NetServer { ctx, local_addr, plane: Some(plane), batch: Some(batch) })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connection-plane counters.
    pub fn stats(&self) -> NetStatsSnapshot {
        self.ctx.stats.snapshot()
    }

    /// The micro-batch plane's latency/batching summary. Reads the stats
    /// shared with the executors directly, so the same path is valid
    /// before, during and after [`NetServer::stop`] — there is no cached
    /// "final" snapshot to race against.
    pub fn batch_stats(&self) -> StatsSnapshot {
        self.ctx.serve_stats.snapshot()
    }

    /// The full observability snapshot this server exposes over the wire
    /// (per-server counters, batch-plane stats, process registry, pool
    /// profile, slowest traces), as a JSON document.
    pub fn snapshot_json(&self) -> String {
        snapshot_json(&self.ctx)
    }

    /// Stop the event plane (open connections get a best-effort
    /// `ShuttingDown` notice), then stop the batch server. Idempotent;
    /// also run on drop.
    pub fn stop(&mut self) {
        if let Some(mut p) = self.plane.take() {
            p.stop();
        }
        if let Some(mut b) = self.batch.take() {
            b.stop();
            // stats live on in ctx.serve_stats — nothing to capture
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Render the full stats snapshot for one server (the `Stats` frame body;
/// schema in `docs/OBSERVABILITY.md`).
fn snapshot_json(ctx: &ConnCtx) -> String {
    let ring = ctx.traces.snapshot();
    Json::obj(vec![
        ("server", ctx.stats.to_json()),
        ("batch", ctx.serve_stats.to_json()),
        ("process", obs::global().snapshot_json()),
        ("pool", crate::linalg::pool::profile().to_json()),
        ("plane", ctx.plane_stats.to_json()),
        ("traces", obs::traces_json(&ctx.traces.slowest(8))),
        ("traces_dropped", Json::from(ctx.traces.dropped() as usize)),
        ("trace_ids", obs::trace_ids_json(&ring)),
    ])
    .to_string()
}

/// Server preamble + hello frame, encoded once at startup.
fn hello_bytes(registry: &Registry) -> Vec<u8> {
    let models = registry
        .catalog()
        .into_iter()
        .map(|m| ModelEntry {
            name: m.name,
            in_dim: m.in_dim as u32,
            out_dim: m.out_dim as u32,
        })
        .collect();
    let mut out = proto::encode_preamble().to_vec();
    out.extend_from_slice(&Frame::Hello(HelloFrame { models }).to_bytes());
    out
}

#[inline]
fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// The net server's [`Dispatch`] implementation: validation, row budget,
/// batch submission, reply assembly.
struct ServerDispatch {
    ctx: Arc<ConnCtx>,
}

impl Dispatch for ServerDispatch {
    fn hello_bytes(&self) -> Vec<u8> {
        self.ctx.hello.clone()
    }

    fn snapshot_json(&self) -> String {
        snapshot_json(&self.ctx)
    }

    fn shed_message(&self) -> String {
        format!("connection limit reached (in-flight budget {})", self.ctx.inflight_max)
    }

    fn event(&self, ev: PlaneEvent) {
        match ev {
            PlaneEvent::Connection => self.ctx.stats.inc_connections(),
            PlaneEvent::ConnectionShed => self.ctx.stats.inc_connections_shed(),
            PlaneEvent::FrameTimeout => self.ctx.stats.inc_frame_timeout(),
            PlaneEvent::StatsServed => self.ctx.stats.inc_stats(),
            PlaneEvent::WriteqShed => {
                // a pipeline-bound shed is a request shed with its own
                // sub-counter
                self.ctx.stats.inc_shed();
                self.ctx.stats.inc_writeq_shed();
            }
            // backends never answer fleet queries (the plane rejects tag 7
            // as malformed when the dispatch declines), so this is
            // unreachable here — routers own the arm
            PlaneEvent::FleetStatsServed => {}
        }
    }

    fn record_trace(&self, trace: &Trace) {
        if self.ctx.traces.record(trace) {
            obs::counter(CounterId::TracesRecorded).inc();
        } else {
            obs::counter(CounterId::TracesDropped).inc();
        }
    }

    fn on_request(
        &self,
        rctx: RequestCtx,
        req: RequestFrame,
        sink: &CompletionSink,
    ) -> RequestAction {
        let ctx = &self.ctx;
        let id = req.id;
        // validate against the registry *before* spending compute
        let Some(loaded) = ctx.registry.get(&req.model) else {
            ctx.stats.inc_failed();
            return RequestAction::Reply(plane::error_bytes(
                id,
                ErrorCode::UnknownModel,
                format!("model '{}' not registered", req.model),
            ));
        };
        let in_dim = loaded.engine.in_dim();
        let out_dim = loaded.engine.out_dim();
        let rows = req.rows as usize;
        if req.cols as usize != in_dim {
            ctx.stats.inc_failed();
            return RequestAction::Reply(plane::error_bytes(
                id,
                ErrorCode::WrongDims,
                format!("model '{}' expects {in_dim} features, got {}", req.model, req.cols),
            ));
        }
        // reject requests whose *response* could not be framed: without
        // this a small-input/large-output model could make the server pay
        // the full forward pass only to emit a frame every conforming
        // client must reject as oversized
        let response_bytes = rows
            .checked_mul(out_dim)
            .and_then(|n| n.checked_mul(4))
            .and_then(|n| n.checked_add(64)); // envelope + header slack
        let response_fits = matches!(response_bytes, Some(n) if n <= ctx.max_frame);
        if !response_fits {
            ctx.stats.inc_failed();
            return RequestAction::Reply(plane::error_bytes(
                id,
                ErrorCode::WrongDims,
                format!(
                    "a {rows}-row response ({out_dim} logits/row) would exceed the \
                     frame cap of {} bytes",
                    ctx.max_frame
                ),
            ));
        }
        // bounded in-flight budget (counted in rows): shed, don't queue
        if !try_acquire(&ctx.inflight, ctx.inflight_max, rows) {
            ctx.stats.inc_shed();
            return RequestAction::Reply(plane::error_bytes(
                id,
                ErrorCode::Overloaded,
                format!(
                    "in-flight budget exhausted ({} rows in flight, budget {}, request {rows})",
                    ctx.inflight.load(Ordering::Relaxed),
                    ctx.inflight_max
                ),
            ));
        }
        if obs::enabled() {
            obs::gauge(GaugeId::NetInflight).set(ctx.inflight.load(Ordering::Relaxed) as f64);
        }
        // submit row jobs with completion callbacks; the last row to
        // settle assembles and posts the reply — this net thread moves on
        // immediately
        let agg = Arc::new(Mutex::new(PendingAgg {
            id,
            trace_id: req.trace.map(|t| t.trace_id).unwrap_or(0),
            rows,
            out_dim,
            data: vec![0.0; rows * out_dim],
            remaining: rows,
            err: None,
            queue_ns: 0,
            assembly_ns: 0,
            compute_ns: 0,
            accept_ns: rctx.accept_ns,
            decode_ns: rctx.decode_ns,
        }));
        let cols = req.cols as usize;
        let mut data = req.data;
        for r in 0..rows {
            // single-row fast path: move the frame-decoded vector straight
            // into the job (no input copy); multi-row pays one row copy
            let row = if rows == 1 {
                std::mem::take(&mut data)
            } else {
                data[r * cols..(r + 1) * cols].to_vec()
            };
            let mut guard = RowGuard {
                ctx: Arc::clone(ctx),
                agg: Arc::clone(&agg),
                sink: sink.clone(),
                key: rctx.key,
                row: r,
                done: false,
            };
            let submitted =
                ctx.client.submit_with(&req.model, row, move |o| guard.settle(Some(o)));
            if submitted.is_err() {
                // the batch plane is gone. Row `r`'s callback was dropped
                // unrun, so its guard already settled it (error recorded,
                // budget row released); rows `r+1..` were never submitted
                // — settle them here so the request still answers.
                let unsent = rows - r - 1;
                if unsent > 0 {
                    ctx.release_rows(unsent);
                    let finish = {
                        let mut a = agg.lock().unwrap();
                        a.remaining -= unsent;
                        a.remaining == 0
                    };
                    if finish {
                        send_completion(ctx, &agg, sink, rctx.key);
                    }
                }
                break;
            }
        }
        RequestAction::Async
    }
}

/// Batch-plane aggregation state for one in-flight request: logits land
/// row by row; the response waits on the slowest row, so span times keep
/// the worst value.
struct PendingAgg {
    id: u64,
    /// Propagated trace id (0 = untraced); stitches this backend span to
    /// the router/client span sharing the id.
    trace_id: u64,
    rows: usize,
    out_dim: usize,
    data: Vec<f32>,
    /// Rows not yet settled (answered, failed, or dropped).
    remaining: usize,
    /// First error wins; its presence turns the reply into an error
    /// frame.
    err: Option<(ErrorCode, String)>,
    queue_ns: u64,
    assembly_ns: u64,
    compute_ns: u64,
    accept_ns: u64,
    decode_ns: u64,
}

/// Settles exactly one row of a pending request — normally through the
/// batch executor's completion callback, or via `Drop` if the callback is
/// discarded unrun (executor panic, shutdown race). Either way the budget
/// row is released and the request can still answer: no path leaks budget
/// or hangs a client.
struct RowGuard {
    ctx: Arc<ConnCtx>,
    agg: Arc<Mutex<PendingAgg>>,
    sink: CompletionSink,
    key: ConnKey,
    row: usize,
    done: bool,
}

impl RowGuard {
    fn settle(&mut self, outcome: Option<JobOutcome>) {
        if self.done {
            return;
        }
        self.done = true;
        self.ctx.release_rows(1);
        let finish = {
            let mut a = self.agg.lock().unwrap();
            match outcome {
                Some(o) => {
                    a.queue_ns = a.queue_ns.max(o.queue_ns);
                    a.assembly_ns = a.assembly_ns.max(o.assembly_ns);
                    a.compute_ns = a.compute_ns.max(o.compute_ns);
                    match o.result {
                        Ok(logits) => {
                            let start = self.row * a.out_dim;
                            let n = logits.len().min(a.out_dim);
                            a.data[start..start + n].copy_from_slice(&logits[..n]);
                        }
                        Err(msg) => {
                            if a.err.is_none() {
                                a.err = Some((ErrorCode::Internal, msg));
                            }
                        }
                    }
                }
                None => {
                    if a.err.is_none() {
                        a.err = Some((
                            ErrorCode::Internal,
                            "server dropped the request".to_string(),
                        ));
                    }
                }
            }
            a.remaining -= 1;
            a.remaining == 0
        };
        if finish {
            send_completion(&self.ctx, &self.agg, &self.sink, self.key);
        }
    }
}

impl Drop for RowGuard {
    fn drop(&mut self) {
        self.settle(None);
    }
}

/// Assemble the final reply for a fully settled request and post it back
/// to the owning net thread. Counters bump here (before the write), as
/// they always have.
fn send_completion(ctx: &ConnCtx, agg: &Mutex<PendingAgg>, sink: &CompletionSink, key: ConnKey) {
    let (bytes, trace) = {
        let mut a = agg.lock().unwrap();
        match a.err.take() {
            Some((code, message)) => {
                ctx.stats.inc_failed();
                (plane::error_bytes(a.id, code, message), None)
            }
            None => {
                ctx.stats.inc_ok();
                let data = std::mem::take(&mut a.data);
                let frame = Frame::Response(proto::ResponseFrame {
                    id: a.id,
                    rows: a.rows as u32,
                    cols: a.out_dim as u32,
                    data,
                });
                let t_frame = Instant::now();
                let bytes = frame.to_bytes();
                let frame_ns = dur_ns(t_frame.elapsed());
                let trace = obs::enabled().then(|| TraceDraft {
                    id: a.id,
                    trace_id: a.trace_id,
                    accept_ns: a.accept_ns,
                    decode_ns: a.decode_ns,
                    queue_ns: a.queue_ns,
                    assembly_ns: a.assembly_ns,
                    compute_ns: a.compute_ns,
                    frame_ns,
                });
                (bytes, trace)
            }
        }
    };
    sink.send(Completion { key, bytes, trace });
}

/// Claim `n` rows of the in-flight budget; `false` (shed) when the budget
/// cannot cover them. A request larger than the whole budget is always
/// shed — by construction it can never fit.
fn try_acquire(inflight: &AtomicUsize, max: usize, n: usize) -> bool {
    let mut cur = inflight.load(Ordering::Relaxed);
    loop {
        if cur + n > max {
            return false;
        }
        match inflight.compare_exchange_weak(
            cur,
            cur + n,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_acquire_and_shed() {
        let b = AtomicUsize::new(0);
        assert!(try_acquire(&b, 4, 3));
        assert!(try_acquire(&b, 4, 1));
        assert!(!try_acquire(&b, 4, 1), "budget exhausted must shed");
        b.fetch_sub(3, Ordering::Relaxed);
        assert!(try_acquire(&b, 4, 2));
        // a request larger than the whole budget can never fit
        let b = AtomicUsize::new(0);
        assert!(!try_acquire(&b, 4, 5));
    }

    #[test]
    fn default_config_is_sane() {
        let c = NetConfig::default();
        assert!(c.max_connections >= 1);
        assert!(c.net_threads >= 1);
        assert!(c.max_inflight >= 1);
        assert!(c.inflight_budget >= 1);
        assert_eq!(c.max_frame_bytes, proto::DEFAULT_MAX_FRAME);
        assert!(c.trace_slots >= 2);
    }
}
