//! LCQ-RPC connection plane: a TCP listener feeding the in-process
//! micro-batch server.
//!
//! Layout (drawn out in `docs/ARCHITECTURE.md`):
//!
//! * an **acceptor** thread blocks in `accept()` and hands sockets to a
//!   bounded connection queue; when every handler is busy and the queue is
//!   full, the connection is **shed** at the door with an
//!   [`ErrorCode::Overloaded`] handshake instead of being silently queued
//!   forever;
//! * a fixed set of `max_connections` **handler** threads (one blocking
//!   connection each, fanned out via [`crate::linalg::pool::run_scoped`] —
//!   real scoped threads, so parked connections never occupy the compute
//!   pool's task slots) runs the handshake and request loop;
//! * decoded request rows are submitted to the shared
//!   [`MicroBatchServer`] **in place** ([`Client::submit`] hands the
//!   frame-decoded `Vec<f32>` straight to the engine), so the wire → batch
//!   path performs no per-request input copy;
//! * a **bounded in-flight budget** (`NetConfig::inflight_budget`, counted
//!   in rows) sheds excess requests with [`ErrorCode::Overloaded`] before
//!   they touch the compute plane — explicit backpressure instead of
//!   unbounded queueing.
//!
//! Handler sockets carry a short read timeout so every blocking read
//! doubles as a shutdown poll; [`NetServer::stop`] (also run on drop)
//! stops the acceptor, joins the handlers, then stops the batch server —
//! in-flight requests are answered before the engine goes away.

use crate::net::proto::{
    self, ErrorCode, ErrorFrame, Frame, FrameReader, HelloFrame, ModelEntry, RequestFrame,
    WireError,
};
use crate::serve::{Client, MicroBatchServer, Registry, ServerConfig, StatsSnapshot};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Read-timeout tick at which connection handlers re-check the shutdown
/// flag (mirrors the micro-batcher's poll).
const SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Cap on any single write (handshakes, shed notices, responses): a
/// stalled peer must not pin a handler forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Deadline for the unauthenticated pre-hello phase: a connection that
/// has not delivered its preamble within this window is dropped. Without
/// it, `max_connections` silent connects (`nc host port`) would pin every
/// handler forever and shed all future traffic.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Connection-plane knobs (config file: the `"net"` object **inside the
/// `"serve"` section** — the top-level `"net"` key names the MLP
/// architecture; see [`crate::config::NetSettings`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// Listen address, `host:port`. Port 0 binds an ephemeral port
    /// (report it with [`NetServer::local_addr`]) — the loopback tests and
    /// benches rely on this.
    pub bind_addr: String,
    /// Concurrent connections served; one handler thread each. Beyond
    /// this (plus a same-sized accept backlog), connections are shed with
    /// [`ErrorCode::Overloaded`] at handshake time.
    pub max_connections: usize,
    /// In-flight request budget in **rows**: rows submitted to the batch
    /// server but not yet answered. Requests that would exceed it are
    /// shed with [`ErrorCode::Overloaded`] — the backpressure signal.
    pub inflight_budget: usize,
    /// Largest accepted frame payload, bytes (guards allocation).
    pub max_frame_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            bind_addr: "127.0.0.1:7070".to_string(),
            max_connections: 64,
            inflight_budget: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME,
        }
    }
}

/// Monotonic connection-plane counters (all-time, point-in-time read).
#[derive(Clone, Debug, Default)]
pub struct NetStatsSnapshot {
    /// Connections accepted by the listener.
    pub connections: u64,
    /// Connections shed at the door (handler pool + backlog full).
    pub connections_shed: u64,
    /// Requests answered with logits.
    pub requests_ok: u64,
    /// Requests shed by the in-flight budget.
    pub requests_shed: u64,
    /// Requests answered with a non-overload error.
    pub requests_failed: u64,
}

#[derive(Default)]
struct NetStats {
    connections: AtomicU64,
    connections_shed: AtomicU64,
    requests_ok: AtomicU64,
    requests_shed: AtomicU64,
    requests_failed: AtomicU64,
}

impl NetStats {
    fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            connections_shed: self.connections_shed.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            requests_failed: self.requests_failed.load(Ordering::Relaxed),
        }
    }
}

/// Everything a connection handler needs, shared by `Arc`.
struct ConnCtx {
    registry: Arc<Registry>,
    client: Client,
    shutdown: AtomicBool,
    /// Rows currently submitted to the batch server and unanswered.
    inflight: AtomicUsize,
    inflight_max: usize,
    max_frame: usize,
    stats: NetStats,
    /// Precomputed server preamble + hello frame (catalog), written to
    /// every accepted connection.
    hello: Vec<u8>,
}

/// The TCP serving front end: listener + handler pool + micro-batch
/// server, one self-contained unit (see module docs).
pub struct NetServer {
    ctx: Arc<ConnCtx>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    conn_plane: Option<JoinHandle<()>>,
    batch: Option<MicroBatchServer>,
    /// Final batch-plane snapshot, captured when [`NetServer::stop`]
    /// retires the micro-batch server (so stats survive the stop).
    final_batch_stats: Option<StatsSnapshot>,
}

impl NetServer {
    /// Bind `net_cfg.bind_addr`, start the micro-batch server with
    /// `serve_cfg`, and begin accepting LCQ-RPC connections.
    pub fn start(
        registry: Arc<Registry>,
        serve_cfg: ServerConfig,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(&net_cfg.bind_addr)
            .with_context(|| format!("binding {}", net_cfg.bind_addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let batch = MicroBatchServer::start(Arc::clone(&registry), serve_cfg);
        let max_conns = net_cfg.max_connections.max(1);
        let ctx = Arc::new(ConnCtx {
            hello: hello_bytes(&registry),
            client: batch.client(),
            registry,
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            inflight_max: net_cfg.inflight_budget.max(1),
            max_frame: net_cfg.max_frame_bytes.max(1024),
            stats: NetStats::default(),
        });
        // bounded hand-off from the acceptor to the handlers; its slack
        // doubles as the accept backlog before connections are shed
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(max_conns);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let conn_plane = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("lcq-net-conns".to_string())
                .spawn(move || handler_pool(ctx, conn_rx, max_conns))
                .context("spawning connection plane")?
        };
        let acceptor = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("lcq-net-accept".to_string())
                .spawn(move || acceptor_loop(listener, conn_tx, ctx))
                .context("spawning acceptor")?
        };
        Ok(NetServer {
            ctx,
            local_addr,
            acceptor: Some(acceptor),
            conn_plane: Some(conn_plane),
            batch: Some(batch),
            final_batch_stats: None,
        })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connection-plane counters.
    pub fn stats(&self) -> NetStatsSnapshot {
        self.ctx.stats.snapshot()
    }

    /// The underlying micro-batch server's latency/batching summary
    /// (after [`NetServer::stop`], the final snapshot).
    pub fn batch_stats(&self) -> StatsSnapshot {
        match &self.batch {
            Some(b) => b.stats(),
            None => self
                .final_batch_stats
                .clone()
                .expect("snapshot captured when the batch server was stopped"),
        }
    }

    /// Stop accepting, join every handler (in-flight requests are
    /// answered), then stop the batch server. Idempotent; also run on
    /// drop.
    pub fn stop(&mut self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        // the acceptor blocks in accept(): poke it with a throwaway
        // connection so it observes the flag
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // the acceptor owned the connection queue's sender; handlers
        // finish their current connection (bounded by the shutdown poll),
        // then exit on the disconnected queue
        if let Some(h) = self.conn_plane.take() {
            let _ = h.join();
        }
        if let Some(mut b) = self.batch.take() {
            b.stop();
            self.final_batch_stats = Some(b.stats());
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Server preamble + hello frame, encoded once at startup.
fn hello_bytes(registry: &Registry) -> Vec<u8> {
    let models = registry
        .catalog()
        .into_iter()
        .map(|m| ModelEntry {
            name: m.name,
            in_dim: m.in_dim as u32,
            out_dim: m.out_dim as u32,
        })
        .collect();
    let mut out = proto::encode_preamble().to_vec();
    out.extend_from_slice(&Frame::Hello(HelloFrame { models }).to_bytes());
    out
}

fn acceptor_loop(
    listener: TcpListener,
    conn_tx: mpsc::SyncSender<TcpStream>,
    ctx: Arc<ConnCtx>,
) {
    for stream in listener.incoming() {
        if ctx.shutdown.load(Ordering::Relaxed) {
            return; // drops conn_tx: handlers drain the backlog and exit
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // accept failures (EMFILE under fd pressure, transient
                // network errors) can repeat instantly: back off briefly
                // instead of busy-spinning a core exactly when the
                // process is already overloaded
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        ctx.stats.connections.fetch_add(1, Ordering::Relaxed);
        let _ = stream.set_nodelay(true);
        match conn_tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(stream)) => {
                // every handler busy and the backlog full: shed at the
                // door with an explicit overload handshake
                ctx.stats.connections_shed.fetch_add(1, Ordering::Relaxed);
                shed_connection(stream, ctx.inflight_max);
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

/// Best-effort overload handshake for a connection the plane cannot take:
/// preamble + `Overloaded` error frame, then close.
fn shed_connection(mut stream: TcpStream, budget: usize) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut bytes = proto::encode_preamble().to_vec();
    bytes.extend_from_slice(
        &Frame::Error(ErrorFrame {
            id: 0,
            code: ErrorCode::Overloaded,
            message: format!("connection limit reached (in-flight budget {budget})"),
        })
        .to_bytes(),
    );
    let _ = stream.write_all(&bytes);
}

/// `max_conns` blocking connection handlers on scoped threads. Handlers
/// block on sockets and channel replies, so they use `run_scoped` (real
/// threads), never the compute pool's task slots.
fn handler_pool(
    ctx: Arc<ConnCtx>,
    conn_rx: Arc<Mutex<Receiver<TcpStream>>>,
    max_conns: usize,
) {
    crate::linalg::pool::run_scoped(max_conns, |_| loop {
        let next = { conn_rx.lock().unwrap().recv() };
        match next {
            Ok(stream) => handle_conn(stream, &ctx),
            Err(_) => return, // acceptor gone and backlog drained
        }
    });
}

/// One connection, handshake to close.
fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_read_timeout(Some(SHUTDOWN_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    // --- handshake: read the client preamble (polling for shutdown,
    //     bounded by HANDSHAKE_TIMEOUT so silent connects free the
    //     handler instead of pinning it) ------------------------------
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    let mut filled = 0;
    let handshake_start = std::time::Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Relaxed)
            || handshake_start.elapsed() > HANDSHAKE_TIMEOUT
        {
            return;
        }
        match proto::poll_exact(&mut stream, &mut pre, &mut filled) {
            Ok(true) => break,
            Ok(false) => continue,
            Err(_) => return,
        }
    }
    match proto::decode_preamble(&pre) {
        Ok(v) if v == proto::VERSION => {}
        Ok(v) => {
            // speaks LCQ-RPC but a different version: say so, then close
            let mut bytes = proto::encode_preamble().to_vec();
            bytes.extend_from_slice(
                &Frame::Error(ErrorFrame {
                    id: 0,
                    code: ErrorCode::UnsupportedVersion,
                    message: format!("server speaks v{}, client sent v{v}", proto::VERSION),
                })
                .to_bytes(),
            );
            let _ = stream.write_all(&bytes);
            return;
        }
        Err(_) => return, // not our protocol: close without a reply
    }
    // --- hello: preamble + model catalog (precomputed) -----------------
    if stream.write_all(&ctx.hello).is_err() {
        return;
    }
    // --- request loop ---------------------------------------------------
    let mut reader = FrameReader::new(ctx.max_frame);
    loop {
        if ctx.shutdown.load(Ordering::Relaxed) {
            let _ = proto::write_frame(
                &mut stream,
                &Frame::Error(ErrorFrame {
                    id: 0,
                    code: ErrorCode::ShuttingDown,
                    message: "server shutting down".to_string(),
                }),
            );
            return;
        }
        match reader.poll_frame(&mut stream) {
            Ok(None) => continue, // read-timeout tick
            Ok(Some(Frame::Request(req))) => {
                if !answer_request(&mut stream, ctx, req) {
                    return;
                }
            }
            Ok(Some(_)) => {
                // clients may only send requests
                let _ = proto::write_frame(
                    &mut stream,
                    &Frame::Error(ErrorFrame {
                        id: 0,
                        code: ErrorCode::Malformed,
                        message: "unexpected frame type from client".to_string(),
                    }),
                );
                return;
            }
            Err(WireError::Closed) => return, // clean close
            Err(WireError::Io(_)) => return,
            Err(e) => {
                // protocol violation: the stream is no longer framed —
                // report once and close
                let _ = proto::write_frame(
                    &mut stream,
                    &Frame::Error(ErrorFrame {
                        id: 0,
                        code: ErrorCode::Malformed,
                        message: e.to_string(),
                    }),
                );
                return;
            }
        }
    }
}

/// Validate, budget, submit and answer one request. Returns `false` when
/// the connection should close (write failure).
fn answer_request(stream: &mut TcpStream, ctx: &ConnCtx, req: RequestFrame) -> bool {
    let id = req.id;
    let fail = |stream: &mut TcpStream, code: ErrorCode, message: String| -> bool {
        proto::write_frame(stream, &Frame::Error(ErrorFrame { id, code, message })).is_ok()
    };
    // validate against the registry *before* spending compute
    let Some(loaded) = ctx.registry.get(&req.model) else {
        ctx.stats.requests_failed.fetch_add(1, Ordering::Relaxed);
        return fail(
            stream,
            ErrorCode::UnknownModel,
            format!("model '{}' not registered", req.model),
        );
    };
    let in_dim = loaded.engine.in_dim();
    let out_dim = loaded.engine.out_dim();
    let rows = req.rows as usize;
    if req.cols as usize != in_dim {
        ctx.stats.requests_failed.fetch_add(1, Ordering::Relaxed);
        return fail(
            stream,
            ErrorCode::WrongDims,
            format!("model '{}' expects {in_dim} features, got {}", req.model, req.cols),
        );
    }
    // reject requests whose *response* could not be framed: without this
    // a small-input/large-output model could make the server pay the full
    // forward pass only to emit a frame every conforming client must
    // reject as oversized
    let response_bytes = rows
        .checked_mul(out_dim)
        .and_then(|n| n.checked_mul(4))
        .and_then(|n| n.checked_add(64)); // envelope + header slack
    let response_fits = matches!(response_bytes, Some(n) if n <= ctx.max_frame);
    if !response_fits {
        ctx.stats.requests_failed.fetch_add(1, Ordering::Relaxed);
        return fail(
            stream,
            ErrorCode::WrongDims,
            format!(
                "a {rows}-row response ({out_dim} logits/row) would exceed the \
                 frame cap of {} bytes",
                ctx.max_frame
            ),
        );
    }
    // bounded in-flight budget (counted in rows): shed, don't queue
    if !try_acquire(&ctx.inflight, ctx.inflight_max, rows) {
        ctx.stats.requests_shed.fetch_add(1, Ordering::Relaxed);
        return fail(
            stream,
            ErrorCode::Overloaded,
            format!(
                "in-flight budget exhausted ({} rows in flight, budget {}, request {rows})",
                ctx.inflight.load(Ordering::Relaxed),
                ctx.inflight_max
            ),
        );
    }
    let outcome = submit_rows(ctx, req);
    ctx.inflight.fetch_sub(rows, Ordering::Relaxed);
    match outcome {
        Ok(data) => {
            ctx.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
            let frame = Frame::Response(proto::ResponseFrame {
                id,
                rows: rows as u32,
                cols: out_dim as u32,
                data,
            });
            proto::write_frame(stream, &frame).is_ok()
        }
        Err((code, message)) => {
            ctx.stats.requests_failed.fetch_add(1, Ordering::Relaxed);
            fail(stream, code, message)
        }
    }
}

/// Submit a request's rows to the batch server and collect the logits.
///
/// The single-row fast path moves the frame-decoded `Vec<f32>` straight
/// into the job — the engine gathers from that buffer in place, so the
/// socket → logits path copies input floats exactly once (the kernel read
/// into the frame buffer). Multi-row requests split into per-row jobs
/// (they coalesce back into one engine batch via the model group) and pay
/// one row copy each; batch clients are the convenience path.
///
/// Every submission gets a **fresh** reply channel: if the batch plane
/// ever drops a job without answering (an executor panic), the channel
/// disconnects and `recv` errors instead of blocking this handler — and
/// [`NetServer::stop`] — forever. The per-request channel allocation is
/// the price of that liveness guarantee.
fn submit_rows(
    ctx: &ConnCtx,
    req: RequestFrame,
) -> std::result::Result<Vec<f32>, (ErrorCode, String)> {
    let rows = req.rows as usize;
    let stopping = |e: String| (ErrorCode::ShuttingDown, e);
    let dropped = || (ErrorCode::Internal, "server dropped the request".to_string());
    if rows == 1 {
        let (tx, rx) = mpsc::channel();
        ctx.client.submit(&req.model, req.data, tx).map_err(stopping)?;
        return match rx.recv() {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(msg)) => Err((ErrorCode::Internal, msg)),
            Err(_) => Err(dropped()),
        };
    }
    let cols = req.cols as usize;
    let mut pending = Vec::with_capacity(rows);
    for r in 0..rows {
        let (tx, rx) = mpsc::channel();
        let row = req.data[r * cols..(r + 1) * cols].to_vec();
        ctx.client.submit(&req.model, row, tx).map_err(stopping)?;
        pending.push(rx);
    }
    let mut out = Vec::new();
    for rx in pending {
        match rx.recv() {
            Ok(Ok(logits)) => out.extend_from_slice(&logits),
            Ok(Err(msg)) => return Err((ErrorCode::Internal, msg)),
            Err(_) => return Err(dropped()),
        }
    }
    Ok(out)
}

/// Claim `n` rows of the in-flight budget; `false` (shed) when the budget
/// cannot cover them. A request larger than the whole budget is always
/// shed — by construction it can never fit.
fn try_acquire(inflight: &AtomicUsize, max: usize, n: usize) -> bool {
    let mut cur = inflight.load(Ordering::Relaxed);
    loop {
        if cur + n > max {
            return false;
        }
        match inflight.compare_exchange_weak(
            cur,
            cur + n,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_acquire_and_shed() {
        let b = AtomicUsize::new(0);
        assert!(try_acquire(&b, 4, 3));
        assert!(try_acquire(&b, 4, 1));
        assert!(!try_acquire(&b, 4, 1), "budget exhausted must shed");
        b.fetch_sub(3, Ordering::Relaxed);
        assert!(try_acquire(&b, 4, 2));
        // a request larger than the whole budget can never fit
        let b = AtomicUsize::new(0);
        assert!(!try_acquire(&b, 4, 5));
    }

    #[test]
    fn default_config_is_sane() {
        let c = NetConfig::default();
        assert!(c.max_connections >= 1);
        assert!(c.inflight_budget >= 1);
        assert_eq!(c.max_frame_bytes, proto::DEFAULT_MAX_FRAME);
    }
}
