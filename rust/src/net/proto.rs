//! LCQ-RPC wire protocol, version 3: length-prefixed, checksummed binary
//! frames over a byte stream.
//!
//! The framing mirrors the `.lcq` file discipline (`docs/lcq-format.md`):
//! little-endian integers, strings as `u32 length + UTF-8 bytes`, and an
//! FNV-1a 64 checksum so corruption and truncation fail loudly on the
//! reading side. The full byte-level specification for third-party
//! implementors lives in `docs/wire-protocol.md`; the round-trip and
//! rejection tests below pin this module to that document.
//!
//! ```text
//! connection:  client preamble | server preamble | Hello frame | frames…
//! preamble:    magic "LCQR" | version u32
//! frame:       payload_len u32 | payload | fnv1a-64(payload) u64
//! payload:     tag u8 | tag-specific fields
//!              (Request/Response/Error/Hello/StatsRequest/StatsResponse/
//!               FleetStatsRequest/FleetStatsResponse)
//! ```
//!
//! v3 adds an optional 9-byte trace-context tail on `Request` frames
//! (`trace_id u64 | parent_span u8` after the f32 data — absent means an
//! untraced v2-shaped request) and the fleet-stats frame pair (tags 7/8)
//! answered by the fabric router. Servers accept v2 peers
//! ([`MIN_VERSION`]); a trace context arriving on a v2-negotiated
//! connection is a protocol violation the plane rejects as
//! [`ErrorCode::Malformed`].
//!
//! Decoding never panics on hostile input: every length is bounds-checked
//! before any allocation ([`FrameReader`] rejects oversized frames from
//! the 4-byte prefix alone), every integer cross-checked before size
//! arithmetic, and failures come back as typed [`WireError`]s so the
//! connection plane can answer with the right [`ErrorCode`].

use crate::serve::format::fnv1a;
use std::io::{ErrorKind, Read, Write};

/// Protocol magic, first on the wire in both directions (`"LCQR"`).
pub const MAGIC: &[u8; 4] = b"LCQR";

/// Protocol version spoken by this implementation. v2 added the stats
/// exposition frames (tags 5/6); v3 adds the optional trace-context tail
/// on requests and the fleet-stats frames (tags 7/8). See
/// `docs/wire-protocol.md` for the version history.
pub const VERSION: u32 = 3;

/// Oldest peer version this implementation still accepts at the
/// handshake. v2 peers simply never send trace contexts or fleet-stats
/// frames; everything else is byte-identical.
pub const MIN_VERSION: u32 = 2;

/// Preamble length: magic + version.
pub const PREAMBLE_LEN: usize = 8;

/// Default cap on a frame's payload size (16 MiB — a 2 M-float batch,
/// far above any sane request). Both sides reject larger frames before
/// allocating.
pub const DEFAULT_MAX_FRAME: usize = 16 << 20;

/// Structured error codes carried by [`ErrorFrame`]s — the wire contract
/// for "what went wrong", so clients can react without parsing messages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// The requested model id is not in the server's registry.
    UnknownModel = 1,
    /// Request columns do not match the model's input dimension.
    WrongDims = 2,
    /// The server shed the request (in-flight budget or connection limit
    /// exhausted) — the backpressure signal; retry later or elsewhere.
    Overloaded = 3,
    /// The frame failed to decode (bad checksum, bad lengths, unknown
    /// tag). The server closes the connection after sending this.
    Malformed = 4,
    /// The request was valid but execution failed server-side.
    Internal = 5,
    /// The peer speaks an incompatible protocol version.
    UnsupportedVersion = 6,
    /// The server is shutting down; no further requests will be answered.
    ShuttingDown = 7,
    /// A deadline expired before the work completed: a request frame made
    /// no progress within the server's per-frame deadline (the connection
    /// is shed and closed), or a routed request exhausted its end-to-end
    /// deadline at the fabric router. Retrying later is safe.
    Timeout = 8,
}

impl ErrorCode {
    /// Wire tag of this code.
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Decode a wire tag; `None` for tags this version does not know.
    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::UnknownModel,
            2 => ErrorCode::WrongDims,
            3 => ErrorCode::Overloaded,
            4 => ErrorCode::Malformed,
            5 => ErrorCode::Internal,
            6 => ErrorCode::UnsupportedVersion,
            7 => ErrorCode::ShuttingDown,
            8 => ErrorCode::Timeout,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorCode::UnknownModel => "unknown model",
            ErrorCode::WrongDims => "wrong dimensions",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Malformed => "malformed frame",
            ErrorCode::Internal => "internal error",
            ErrorCode::UnsupportedVersion => "unsupported version",
            ErrorCode::ShuttingDown => "shutting down",
            ErrorCode::Timeout => "deadline exceeded",
        };
        f.write_str(s)
    }
}

/// Distributed trace context riding on a v3 [`RequestFrame`]: a fleet-wide
/// trace id plus which hop stamped it, so one id stitches the client's
/// observed latency into router and backend stage timings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceContext {
    /// Fleet-wide trace identity (non-zero by convention; 0 is the
    /// "untraced" sentinel in ring snapshots).
    pub trace_id: u64,
    /// Which hop stamped this context: 0 = client origin, 1 = router.
    pub parent_span: u8,
}

/// Inference request: `rows × cols` row-major f32 input for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen id, echoed verbatim in the response.
    pub id: u64,
    /// Registry model name (the wire model id).
    pub model: String,
    /// Batch rows (≥ 1; enforced at decode).
    pub rows: u32,
    /// Features per row; must match the model's input dimension.
    pub cols: u32,
    /// Row-major input, `rows * cols` values.
    pub data: Vec<f32>,
    /// Optional v3 trace context, encoded as a 9-byte tail after `data`.
    /// `None` encodes byte-identically to a v2 request.
    pub trace: Option<TraceContext>,
}

/// Successful inference response: `rows × cols` row-major f32 logits.
#[derive(Clone, Debug, PartialEq)]
pub struct ResponseFrame {
    /// Echo of the request id.
    pub id: u64,
    /// Batch rows (equals the request's).
    pub rows: u32,
    /// Logits per row (the model's output dimension).
    pub cols: u32,
    /// Row-major logits, `rows * cols` values.
    pub data: Vec<f32>,
}

/// Structured failure response. `id == 0` marks connection-level errors
/// not tied to a particular request (handshake rejection, shutdown).
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorFrame {
    /// Echo of the request id, or 0 for connection-level errors.
    pub id: u64,
    /// What went wrong, as a wire enum.
    pub code: ErrorCode,
    /// Human-readable detail (diagnostic only; never parse it).
    pub message: String,
}

/// One model catalog entry in the server's [`HelloFrame`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelEntry {
    /// Registry model name (the wire model id).
    pub name: String,
    /// Features per request row.
    pub in_dim: u32,
    /// Logits per request row.
    pub out_dim: u32,
}

/// The server's first frame after the preamble: the model catalog, so
/// clients can pick a model and validate arity before sending data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HelloFrame {
    /// Every served model, sorted by name.
    pub models: Vec<ModelEntry>,
}

/// Observability snapshot request (v2): ask the server for its current
/// stats. Carries only an id, echoed in the response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsRequestFrame {
    /// Client-chosen id, echoed verbatim in the response.
    pub id: u64,
}

/// Observability snapshot response (v2): a JSON document rendering the
/// server's metrics registry, batch-server stats, pool profile and
/// slowest recent traces (schema documented in `docs/OBSERVABILITY.md`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsResponseFrame {
    /// Echo of the request id.
    pub id: u64,
    /// The snapshot, as a JSON document (diagnostic schema; fields may be
    /// added in later versions without a protocol bump).
    pub json: String,
}

/// Fleet stats request (v3): ask a fabric router to fan `StatsRequest`
/// out to every known backend and return the merged fleet view. Backends
/// reject this frame as [`ErrorCode::Malformed`] — only routers answer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetStatsRequestFrame {
    /// Client-chosen id, echoed verbatim in the response.
    pub id: u64,
}

/// Fleet stats response (v3): per-backend stats sections plus the merged
/// fleet view (summed counters, bucket-merged histograms, health census).
/// Schema documented in `docs/OBSERVABILITY.md`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetStatsResponseFrame {
    /// Echo of the request id.
    pub id: u64,
    /// The merged fleet snapshot, as a JSON document (diagnostic schema;
    /// fields may be added in later versions without a protocol bump).
    pub json: String,
}

/// Any LCQ-RPC frame (the payload tag selects the variant).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Tag 1: inference request (client → server).
    Request(RequestFrame),
    /// Tag 2: inference response (server → client).
    Response(ResponseFrame),
    /// Tag 3: structured error (server → client).
    Error(ErrorFrame),
    /// Tag 4: model catalog (server → client, once, after the preamble).
    Hello(HelloFrame),
    /// Tag 5 (v2): stats snapshot request (client → server).
    StatsRequest(StatsRequestFrame),
    /// Tag 6 (v2): stats snapshot response (server → client).
    StatsResponse(StatsResponseFrame),
    /// Tag 7 (v3): fleet stats request (client → router).
    FleetStatsRequest(FleetStatsRequestFrame),
    /// Tag 8 (v3): fleet stats response (router → client).
    FleetStatsResponse(FleetStatsResponseFrame),
}

/// Everything that can go wrong reading or decoding the wire.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (other than the timeouts [`FrameReader`] absorbs).
    Io(std::io::Error),
    /// The preamble does not start with [`MAGIC`] — not our protocol.
    BadMagic([u8; 4]),
    /// A frame announced a payload larger than the reader's cap.
    Oversized {
        /// Announced payload length.
        len: usize,
        /// The reader's configured cap.
        max: usize,
    },
    /// Frame checksum mismatch — bytes were corrupted in flight.
    Checksum {
        /// Checksum carried by the frame.
        stored: u64,
        /// Checksum computed over the received payload.
        computed: u64,
    },
    /// The payload violates the spec (bad lengths, unknown tag, non-UTF-8
    /// string, truncated fields, trailing bytes…).
    Malformed(String),
    /// The peer closed the connection at a frame boundary.
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad magic {m:02x?} (not LCQ-RPC)"),
            WireError::Oversized { len, max } => {
                write!(f, "frame payload {len} bytes exceeds cap {max}")
            }
            WireError::Checksum { stored, computed } => write!(
                f,
                "frame checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireError::Closed => write!(f, "connection closed"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> WireError {
    WireError::Malformed(msg.into())
}

// ---- preamble ---------------------------------------------------------

/// The 8-byte preamble each side sends first: magic + version.
pub fn encode_preamble() -> [u8; PREAMBLE_LEN] {
    let mut out = [0u8; PREAMBLE_LEN];
    out[..4].copy_from_slice(MAGIC);
    out[4..].copy_from_slice(&VERSION.to_le_bytes());
    out
}

/// Validate the magic and return the peer's version (callers decide
/// whether a different version is acceptable — the server replies with
/// [`ErrorCode::UnsupportedVersion`] and closes on a mismatch).
pub fn decode_preamble(bytes: &[u8; PREAMBLE_LEN]) -> Result<u32, WireError> {
    if &bytes[..4] != MAGIC {
        return Err(WireError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
    }
    Ok(u32::from_le_bytes(bytes[4..8].try_into().unwrap()))
}

// ---- little-endian payload codec --------------------------------------

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(malformed(format!(
                "wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|e| malformed(format!("bad utf8 string: {e}")))
    }
    /// Read exactly `n` f32 values. The byte count is overflow-checked:
    /// a hostile `rows × cols` that survives the product check can still
    /// overflow `× 4`, and the contract is Err, never panic/wrap.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let nbytes = n
            .checked_mul(4)
            .ok_or_else(|| malformed("f32 payload size overflows"))?;
        let bytes = self.take(nbytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    /// Bytes not yet consumed — drives the optional-tail decode: a v2
    /// request ends exactly at the data, a v3 traced request leaves the
    /// 9-byte trace context.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(malformed(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    buf.reserve(vs.len() * 4);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Validate a `rows × cols` shape against an f32 payload that is supposed
/// to fill the rest of the frame.
fn checked_count(rows: u32, cols: u32) -> Result<usize, WireError> {
    if rows == 0 {
        return Err(malformed("empty batch (rows = 0)"));
    }
    (rows as usize)
        .checked_mul(cols as usize)
        .ok_or_else(|| malformed("rows * cols overflows"))
}

impl Frame {
    /// Encode this frame's payload (tag byte + fields; no envelope).
    pub fn payload(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Frame::Request(r) => {
                buf.push(1);
                put_u64(&mut buf, r.id);
                put_str(&mut buf, &r.model);
                put_u32(&mut buf, r.rows);
                put_u32(&mut buf, r.cols);
                put_f32s(&mut buf, &r.data);
                if let Some(t) = r.trace {
                    put_u64(&mut buf, t.trace_id);
                    buf.push(t.parent_span);
                }
            }
            Frame::Response(r) => {
                buf.push(2);
                put_u64(&mut buf, r.id);
                put_u32(&mut buf, r.rows);
                put_u32(&mut buf, r.cols);
                put_f32s(&mut buf, &r.data);
            }
            Frame::Error(e) => {
                buf.push(3);
                put_u64(&mut buf, e.id);
                buf.push(e.code.as_u8());
                put_str(&mut buf, &e.message);
            }
            Frame::Hello(h) => {
                buf.push(4);
                put_u32(&mut buf, h.models.len() as u32);
                for m in &h.models {
                    put_str(&mut buf, &m.name);
                    put_u32(&mut buf, m.in_dim);
                    put_u32(&mut buf, m.out_dim);
                }
            }
            Frame::StatsRequest(s) => {
                buf.push(5);
                put_u64(&mut buf, s.id);
            }
            Frame::StatsResponse(s) => {
                buf.push(6);
                put_u64(&mut buf, s.id);
                put_str(&mut buf, &s.json);
            }
            Frame::FleetStatsRequest(s) => {
                buf.push(7);
                put_u64(&mut buf, s.id);
            }
            Frame::FleetStatsResponse(s) => {
                buf.push(8);
                put_u64(&mut buf, s.id);
                put_str(&mut buf, &s.json);
            }
        }
        buf
    }

    /// Encode the full on-wire envelope: `len | payload | fnv1a(payload)`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(4 + payload.len() + 8);
        put_u32(&mut out, payload.len() as u32);
        let checksum = fnv1a(&payload);
        out.extend_from_slice(&payload);
        put_u64(&mut out, checksum);
        out
    }

    /// Decode a payload (envelope already stripped and checksum verified
    /// by [`FrameReader`]). Rejects unknown tags, bad shapes, non-UTF-8
    /// strings and trailing bytes — never panics on hostile input.
    pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
        let mut c = Cur { buf: payload, pos: 0 };
        let frame = match c.u8()? {
            1 => {
                let id = c.u64()?;
                let model = c.str()?;
                let rows = c.u32()?;
                let cols = c.u32()?;
                let data = c.f32s(checked_count(rows, cols)?)?;
                // v3 optional trace-context tail: exactly 9 more bytes or
                // none at all. Any other remainder is Malformed (1–8 fail
                // inside u64/u8, > 9 trips the trailing-bytes check).
                let trace = if c.remaining() == 0 {
                    None
                } else {
                    Some(TraceContext { trace_id: c.u64()?, parent_span: c.u8()? })
                };
                Frame::Request(RequestFrame { id, model, rows, cols, data, trace })
            }
            2 => {
                let id = c.u64()?;
                let rows = c.u32()?;
                let cols = c.u32()?;
                let data = c.f32s(checked_count(rows, cols)?)?;
                Frame::Response(ResponseFrame { id, rows, cols, data })
            }
            3 => {
                let id = c.u64()?;
                let raw = c.u8()?;
                let code = ErrorCode::from_u8(raw)
                    .ok_or_else(|| malformed(format!("unknown error code {raw}")))?;
                let message = c.str()?;
                Frame::Error(ErrorFrame { id, code, message })
            }
            4 => {
                let n = c.u32()? as usize;
                // each entry is ≥ 12 bytes; bound n before reserving
                if n > payload.len() / 12 {
                    return Err(malformed(format!("hello advertises {n} models")));
                }
                let mut models = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = c.str()?;
                    let in_dim = c.u32()?;
                    let out_dim = c.u32()?;
                    models.push(ModelEntry { name, in_dim, out_dim });
                }
                Frame::Hello(HelloFrame { models })
            }
            5 => Frame::StatsRequest(StatsRequestFrame { id: c.u64()? }),
            6 => {
                let id = c.u64()?;
                let json = c.str()?;
                Frame::StatsResponse(StatsResponseFrame { id, json })
            }
            7 => Frame::FleetStatsRequest(FleetStatsRequestFrame { id: c.u64()? }),
            8 => {
                let id = c.u64()?;
                let json = c.str()?;
                Frame::FleetStatsResponse(FleetStatsResponseFrame { id, json })
            }
            t => return Err(malformed(format!("unknown frame tag {t}"))),
        };
        c.finish()?;
        Ok(frame)
    }
}

/// Write one framed message to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.to_bytes())
}

/// Read exactly `buf.len()` bytes across potentially many `read` calls,
/// tolerating read-timeout ticks: returns `Ok(false)` on a timeout (call
/// again; `filled` tracks progress across calls), `Ok(true)` once full.
/// Used for the fixed-size preamble; frames go through [`FrameReader`].
pub fn poll_exact<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    filled: &mut usize,
) -> Result<bool, WireError> {
    while *filled < buf.len() {
        match r.read(&mut buf[*filled..]) {
            Ok(0) => return Err(WireError::Closed),
            Ok(n) => *filled += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Ok(false)
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(true)
}

/// Incremental frame decoder that survives read timeouts.
///
/// Sockets on the serving side carry a read timeout so connection handlers
/// can poll a shutdown flag — but a timeout can strike mid-frame, after
/// some bytes arrived. `FrameReader` owns the partial state: every call to
/// [`poll_frame`](FrameReader::poll_frame) appends whatever the stream
/// yields and returns `Ok(None)` on a timeout tick, so no byte is ever
/// lost and framing never desynchronizes. Oversized frames are rejected
/// from the 4-byte length prefix, before any payload is buffered.
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
    last_decode_ns: u64,
}

impl FrameReader {
    /// A reader rejecting payloads larger than `max_frame` bytes.
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_frame, last_decode_ns: 0 }
    }

    /// CPU time spent verifying + decoding the most recently returned
    /// frame, in nanoseconds (checksum + payload decode only — socket
    /// wait time is excluded). Feeds the per-request trace's decode span.
    pub fn last_decode_ns(&self) -> u64 {
        self.last_decode_ns
    }

    /// Bytes of partial-frame state currently buffered. Zero at a frame
    /// boundary. The server's per-frame progress deadline keys off this:
    /// a connection that holds partial bytes without completing a frame is
    /// a slow-loris suspect, while an idle one (zero buffered) is fine.
    pub fn buffered_len(&self) -> usize {
        self.buf.len()
    }

    /// Pull bytes from `r` until a full frame is buffered, then decode it.
    ///
    /// * `Ok(Some(frame))` — one frame decoded (more may still be
    ///   buffered; call again before blocking on the socket).
    /// * `Ok(None)` — the read timed out (`WouldBlock`/`TimedOut`); call
    ///   again, buffered partial state is kept.
    /// * `Err(WireError::Closed)` — EOF at a frame boundary (clean close).
    /// * other errors — protocol violation or transport failure; the
    ///   stream is no longer framed and must be dropped.
    pub fn poll_frame<R: Read>(&mut self, r: &mut R) -> Result<Option<Frame>, WireError> {
        let mut chunk = [0u8; 4096];
        loop {
            if self.buf.len() >= 4 {
                let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
                if len > self.max_frame {
                    return Err(WireError::Oversized { len, max: self.max_frame });
                }
                let total = 4 + len + 8;
                if self.buf.len() >= total {
                    let t0 = std::time::Instant::now();
                    let payload = &self.buf[4..4 + len];
                    let stored =
                        u64::from_le_bytes(self.buf[4 + len..total].try_into().unwrap());
                    let computed = fnv1a(payload);
                    if stored != computed {
                        return Err(WireError::Checksum { stored, computed });
                    }
                    let frame = Frame::decode_payload(payload)?;
                    self.buf.drain(..total);
                    self.last_decode_ns =
                        u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
                    return Ok(Some(frame));
                }
            }
            match r.read(&mut chunk) {
                Ok(0) => {
                    return Err(if self.buf.is_empty() {
                        WireError::Closed
                    } else {
                        malformed("connection closed mid-frame")
                    })
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    return Ok(None)
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Request(RequestFrame {
                id: 7,
                model: "lenet300-k2".into(),
                rows: 2,
                cols: 3,
                data: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE, 1e30, -0.125],
                trace: None,
            }),
            Frame::Request(RequestFrame {
                id: 8,
                model: "lenet300-k2".into(),
                rows: 1,
                cols: 3,
                data: vec![0.25, -1.0, 2.0],
                trace: Some(TraceContext { trace_id: 0xDEAD_BEEF_CAFE, parent_span: 1 }),
            }),
            Frame::Response(ResponseFrame {
                id: 7,
                rows: 2,
                cols: 2,
                data: vec![0.5, -0.5, 3.25, 0.0],
            }),
            Frame::Error(ErrorFrame {
                id: 9,
                code: ErrorCode::Overloaded,
                message: "in-flight budget 256 exhausted".into(),
            }),
            Frame::Hello(HelloFrame {
                models: vec![
                    ModelEntry { name: "binary".into(), in_dim: 784, out_dim: 10 },
                    ModelEntry { name: "k4".into(), in_dim: 784, out_dim: 10 },
                ],
            }),
            Frame::StatsRequest(StatsRequestFrame { id: 42 }),
            Frame::StatsResponse(StatsResponseFrame {
                id: 42,
                json: r#"{"counters":{"net_requests_ok":3}}"#.into(),
            }),
            Frame::FleetStatsRequest(FleetStatsRequestFrame { id: 43 }),
            Frame::FleetStatsResponse(FleetStatsResponseFrame {
                id: 43,
                json: r#"{"fleet":{"backends_total":2}}"#.into(),
            }),
        ]
    }

    fn decode_bytes(bytes: &[u8]) -> Result<Frame, WireError> {
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        let mut cur = std::io::Cursor::new(bytes);
        match reader.poll_frame(&mut cur) {
            Ok(Some(f)) => Ok(f),
            Ok(None) => panic!("cursor cannot time out"),
            Err(e) => Err(e),
        }
    }

    #[test]
    fn every_frame_type_round_trips() {
        for frame in sample_frames() {
            let back = decode_bytes(&frame.to_bytes()).unwrap();
            assert_eq!(back, frame);
        }
    }

    #[test]
    fn floats_round_trip_bitwise() {
        let specials = vec![0.0f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1e-42];
        let frame = Frame::Response(ResponseFrame {
            id: 1,
            rows: 1,
            cols: specials.len() as u32,
            data: specials.clone(),
        });
        let Frame::Response(back) = decode_bytes(&frame.to_bytes()).unwrap() else {
            panic!("wrong frame type");
        };
        for (a, b) in back.data.iter().zip(&specials) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn preamble_round_trip_and_bad_magic() {
        let pre = encode_preamble();
        assert_eq!(decode_preamble(&pre).unwrap(), VERSION);
        let mut bad = pre;
        bad[0] = b'X';
        assert!(matches!(decode_preamble(&bad), Err(WireError::BadMagic(_))));
        // a foreign version still decodes (the caller decides what to do)
        let mut v9 = pre;
        v9[4..8].copy_from_slice(&9u32.to_le_bytes());
        assert_eq!(decode_preamble(&v9).unwrap(), 9);
    }

    #[test]
    fn corrupted_checksum_is_rejected() {
        for frame in sample_frames() {
            let mut bytes = frame.to_bytes();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x20;
            match decode_bytes(&bytes) {
                Err(WireError::Checksum { .. }) | Err(WireError::Malformed(_)) => {}
                // a flipped byte in the length prefix may instead announce
                // a giant frame — also a rejection, never a panic
                Err(WireError::Oversized { .. }) => {}
                other => panic!("corruption not detected: {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_mid_frame_is_detected() {
        let bytes = sample_frames()[0].to_bytes();
        for cut in [1usize, 5, bytes.len() - 1] {
            let err = decode_bytes(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Malformed(_)),
                "cut at {cut}: {err:?}"
            );
        }
        // empty stream is a clean close, not a truncation
        assert!(matches!(decode_bytes(&[]), Err(WireError::Closed)));
    }

    #[test]
    fn oversized_frame_rejected_from_prefix_alone() {
        // announce a 1 GiB payload; only the 4-byte prefix is supplied —
        // the reader must reject before trying to buffer anything
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        let prefix = (1u32 << 30).to_le_bytes();
        let mut cur = std::io::Cursor::new(&prefix[..]);
        match reader.poll_frame(&mut cur) {
            Err(WireError::Oversized { len, max }) => {
                assert_eq!(len, 1 << 30);
                assert_eq!(max, DEFAULT_MAX_FRAME);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn malformed_payloads_are_rejected_without_panic() {
        // helper: wrap a raw payload in a valid envelope (correct checksum)
        // so decode_payload is what rejects it
        fn envelope(payload: &[u8]) -> Vec<u8> {
            let mut out = (payload.len() as u32).to_le_bytes().to_vec();
            out.extend_from_slice(payload);
            out.extend_from_slice(&fnv1a(payload).to_le_bytes());
            out
        }
        // unknown tag
        assert!(matches!(decode_bytes(&envelope(&[99])), Err(WireError::Malformed(_))));
        // empty payload
        assert!(matches!(decode_bytes(&envelope(&[])), Err(WireError::Malformed(_))));
        // request with rows = 0
        let mut p = vec![1u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes()); // name len
        p.push(b'm');
        p.extend_from_slice(&0u32.to_le_bytes()); // rows = 0
        p.extend_from_slice(&4u32.to_le_bytes()); // cols
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // request whose data is shorter than rows*cols
        let mut p = vec![1u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        p.push(b'm');
        p.extend_from_slice(&2u32.to_le_bytes()); // rows
        p.extend_from_slice(&3u32.to_le_bytes()); // cols -> wants 24 bytes
        p.extend_from_slice(&[0u8; 8]); // only 2 floats
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // trailing garbage after a valid error frame
        let mut p = sample_frames()[3].payload();
        assert!(matches!(sample_frames()[3], Frame::Error(_)));
        p.push(0xAB);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // error frame with an unknown code
        let mut p = vec![3u8];
        p.extend_from_slice(&0u64.to_le_bytes());
        p.push(200); // no such code
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // rows × cols chosen so the f32 *byte* count wraps usize even
        // though the element count does not — must be Err, never a wrap
        let mut p = vec![1u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        p.push(b'm');
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // rows
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // cols
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // non-utf8 model name
        let mut p = vec![1u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&[0xFF, 0xFE]);
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&0.0f32.to_le_bytes());
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // stats request with trailing bytes
        let mut p = vec![5u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.push(0x00);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // truncated stats request (id cut short)
        let mut p = vec![5u8];
        p.extend_from_slice(&[0u8; 4]);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // stats response whose json length overruns the payload
        let mut p = vec![6u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1000u32.to_le_bytes()); // claims 1000 bytes
        p.extend_from_slice(b"{}"); // supplies 2
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // stats response with non-utf8 json
        let mut p = vec![6u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&[0xFF, 0xFE]);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // request with a partial trace tail: every length 1..=8 after the
        // data is neither "no context" (0) nor a full context (9) — Err
        let base = Frame::Request(RequestFrame {
            id: 1,
            model: "m".into(),
            rows: 1,
            cols: 1,
            data: vec![0.5],
            trace: None,
        })
        .payload();
        for extra in 1usize..=8 {
            let mut p = base.clone();
            p.extend_from_slice(&[0u8; 8][..extra]);
            assert!(
                matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))),
                "trace tail of {extra} bytes must be rejected"
            );
        }
        // request with a full trace tail plus one trailing byte
        let mut p = base.clone();
        p.extend_from_slice(&[0u8; 10]);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // truncated fleet stats request (id cut short)
        let mut p = vec![7u8];
        p.extend_from_slice(&[0u8; 4]);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // fleet stats request with trailing bytes
        let mut p = vec![7u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.push(0x00);
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
        // fleet stats response whose json length overruns the payload
        let mut p = vec![8u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1000u32.to_le_bytes());
        p.extend_from_slice(b"{}");
        assert!(matches!(decode_bytes(&envelope(&p)), Err(WireError::Malformed(_))));
    }

    #[test]
    fn trace_context_tail_is_byte_transparent() {
        // a traced request is exactly the untraced encoding + 9 bytes, so
        // v2 decoders that stop at the data never see ambiguity
        let mut req = RequestFrame {
            id: 5,
            model: "m".into(),
            rows: 1,
            cols: 2,
            data: vec![1.0, 2.0],
            trace: None,
        };
        let bare = Frame::Request(req.clone()).payload();
        req.trace = Some(TraceContext { trace_id: 77, parent_span: 0 });
        let traced = Frame::Request(req).payload();
        assert_eq!(traced.len(), bare.len() + 9);
        assert_eq!(&traced[..bare.len()], &bare[..]);
        assert_eq!(&traced[bare.len()..bare.len() + 8], &77u64.to_le_bytes());
        assert_eq!(traced[bare.len() + 8], 0);
    }

    #[test]
    fn oversized_stats_response_rejected_from_prefix() {
        // a stats response announcing a payload beyond the cap is rejected
        // from the 4-byte prefix, same as any other frame
        let mut reader = FrameReader::new(1024);
        let prefix = (4096u32).to_le_bytes();
        let mut cur = std::io::Cursor::new(&prefix[..]);
        assert!(matches!(
            reader.poll_frame(&mut cur),
            Err(WireError::Oversized { len: 4096, max: 1024 })
        ));
    }

    #[test]
    fn decode_time_is_tracked_per_frame() {
        // big enough that checksum + decode takes measurable time on any
        // monotonic clock
        let frame = Frame::Request(RequestFrame {
            id: 1,
            model: "m".into(),
            rows: 100,
            cols: 100,
            data: vec![0.5; 10_000],
        });
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        assert_eq!(reader.last_decode_ns(), 0);
        let mut cur = std::io::Cursor::new(frame.to_bytes());
        let got = reader.poll_frame(&mut cur).unwrap().unwrap();
        assert_eq!(got, frame);
        assert!(reader.last_decode_ns() > 0);
    }

    /// A reader that yields its bytes in dribs, interleaving WouldBlock
    /// "timeouts" — the shape of a socket with a read timeout set.
    struct Dribble {
        bytes: Vec<u8>,
        pos: usize,
        tick: usize,
    }

    impl Read for Dribble {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.tick += 1;
            if self.tick % 2 == 0 {
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "tick"));
            }
            if self.pos >= self.bytes.len() {
                return Ok(0);
            }
            let n = buf.len().min(3).min(self.bytes.len() - self.pos);
            buf[..n].copy_from_slice(&self.bytes[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn poll_frame_reassembles_across_timeouts_and_packets() {
        let frames = sample_frames();
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.to_bytes());
        }
        let mut r = Dribble { bytes, pos: 0, tick: 0 };
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        let mut got = Vec::new();
        loop {
            match reader.poll_frame(&mut r) {
                Ok(Some(f)) => got.push(f),
                Ok(None) => continue, // timeout tick: partial state kept
                Err(WireError::Closed) => break,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(got, frames);
    }

    #[test]
    fn poll_exact_survives_timeouts() {
        let mut r = Dribble { bytes: encode_preamble().to_vec(), pos: 0, tick: 0 };
        let mut buf = [0u8; PREAMBLE_LEN];
        let mut filled = 0;
        loop {
            match poll_exact(&mut r, &mut buf, &mut filled) {
                Ok(true) => break,
                Ok(false) => continue,
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(decode_preamble(&buf).unwrap(), VERSION);
    }
}
