//! L4 network plane: serve the packed models to **remote** clients over
//! framed TCP (LCQ-RPC).
//!
//! PRs 1–4 built the deployable artifact (`.lcq`), the LUT engine and the
//! pipelined in-process [`MicroBatchServer`] — but its only clients were
//! threads in the same process. This module is the step that turns the
//! serve stack into a *system*: a versioned wire protocol, a connection
//! plane with explicit overload shedding, a client library, and a load
//! generator.
//!
//! * [`proto`] — the LCQ-RPC wire format: magic/version preamble, then
//!   length-prefixed frames with an FNV-1a 64 checksum (the same
//!   corruption discipline as the `.lcq` file format). Requests carry a
//!   model id + row-major f32 input; responses carry logits or a
//!   structured [`ErrorCode`]. Byte-level spec: `docs/wire-protocol.md`.
//! * [`server`] — [`NetServer`]: an event-driven connection plane (PR 9)
//!   — one non-blocking acceptor plus a small fixed pool of net threads,
//!   each multiplexing thousands of sockets through an epoll readiness
//!   loop ([`crate::util::epoll`]) with per-connection partial-frame
//!   state and a bounded write queue — plus a bounded in-flight row
//!   budget that shed-replies instead of queueing unboundedly, and
//!   decoded request rows submitted to the micro-batcher off the net
//!   threads (the event loop never blocks on compute).
//! * [`client`] — [`NetClient`]: blocking connect/infer/infer_batch with
//!   the server's model catalog from the hello frame, transparent
//!   reconnect-on-drop, and pipelined batch mode: up to `max_inflight`
//!   request ids in flight per connection, matched by id on return
//!   (ordering contract: `docs/wire-protocol.md`).
//! * [`loadgen`] — multi-connection load generator reporting p50/p90/p99
//!   latency, throughput, and shed counts (`bench_serve` uses it for the
//!   loopback TCP sweep → `BENCH_net.json`), plus the PR 9 open-loop
//!   scenarios: Poisson bursts, a mostly-idle connection army, and
//!   slow-loris partial frames ([`loadgen::run_poisson`],
//!   [`loadgen::run_idle_army`], [`loadgen::run_slow_loris`]).
//!
//! LCQ-RPC v2 adds a `Stats` frame pair: any live connection can request a
//! JSON observability snapshot — per-server wire counters, batch-plane
//! stats, the process-wide [`crate::obs`] registry, the compute-pool
//! profile, and the slowest recent request traces (`lcquant stats --addr
//! HOST:PORT` prints one; see `docs/OBSERVABILITY.md`).
//!
//! LCQ-RPC **v3** (this PR) makes observability fleet-wide:
//!
//! * `Request` frames may carry a [`proto::TraceContext`] tail (trace id +
//!   parent span); the router adopts or mints the id, stamps it onto the
//!   forwarded request, and records its own pick/forward/backend_wait/
//!   relay span, so one id stitches client → router → backend stage
//!   timings. A trace-less request encodes byte-identically to v2, and
//!   v2-negotiated connections reject the tail as `Malformed`.
//! * A `FleetStats` frame pair: the router answers by fanning `Stats` to
//!   every backend over pooled connections and returns per-backend
//!   sections plus a merged fleet view (summed counters, bucket-exact
//!   [`crate::obs::Histogram`] merge, health census). `lcquant top --addr`
//!   renders a refreshing dashboard from this frame alone.
//!
//! PR 8 adds the **serve fabric** — the multi-node tier:
//!
//! * [`fabric`] — the static shard map (`serve.fabric` config), one
//!   health-tracked, connection-pooled [`fabric::Backend`] per replica
//!   address, and the replica-pick policy (healthy first, never down).
//! * [`router`] — [`RouterServer`]: a front process that speaks plain
//!   LCQ-RPC to clients (its hello is the **merged** backend catalog, so
//!   `NetClient` needs no fabric awareness) and fails requests over
//!   between replicas on drop/overload within a bounded retry budget and
//!   per-request deadline, shedding typed `Overloaded`/`Timeout` errors
//!   when the fabric is exhausted — never a hang. Health/failover
//!   semantics: `docs/FABRIC.md`.
//!
//! Failure paths are exercised deterministically via
//! [`crate::util::fault`], a seeded fault-injection registry wired into
//! the router's forward path and the loadgen's cluster scenario
//! ([`loadgen::run_cluster`]).
//!
//! ```no_run
//! use lcquant::net::{LoadGenConfig, NetClient, NetConfig, NetServer};
//! use lcquant::serve::{Registry, ServerConfig};
//! use std::sync::Arc;
//! # fn demo() -> anyhow::Result<()> {
//! let registry = Arc::new(Registry::load_dir(std::path::Path::new("models"))?);
//! let server =
//!     NetServer::start(registry, ServerConfig::default(), NetConfig::default())?;
//! let addr = server.local_addr().to_string();
//! // elsewhere (another process / machine):
//! let mut client = NetClient::connect(&addr).map_err(|e| anyhow::anyhow!("{e}"))?;
//! let logits = client.infer("lenet300-k2", &[0.0; 784]);
//! # let _ = logits;
//! # Ok(())
//! # }
//! ```
//!
//! [`MicroBatchServer`]: crate::serve::MicroBatchServer
#![warn(missing_docs)]

pub mod client;
pub mod fabric;
pub mod loadgen;
pub(crate) mod plane;
pub mod proto;
pub mod router;
pub mod server;

pub use client::{ClientError, NetClient, RetryPolicy};
pub use fabric::{Fabric, FabricConfig, HealthState, ShardConfig};
pub use loadgen::{
    ClusterConfig, ClusterReport, IdleArmyConfig, IdleArmyReport, LoadGenConfig, LoadReport,
    PoissonConfig, SlowLorisConfig, SlowLorisReport,
};
pub use proto::{ErrorCode, Frame, TraceContext, WireError};
pub use router::{RouterConfig, RouterServer, RouterStatsSnapshot};
pub use server::{NetConfig, NetServer, NetStatsSnapshot};
