//! Multi-connection load generator for LCQ-RPC servers: drive N blocking
//! connections at a target, count outcomes, report latency percentiles
//! and throughput.
//!
//! Connections are blocking request drivers, so they fan out on scoped
//! threads ([`crate::linalg::pool::run_scoped`]) and leave the worker
//! pool to the engine under test — the same discipline as the in-process
//! smoke clients. Overload sheds ([`ErrorCode::Overloaded`]
//! handshakes or error frames) are counted separately from hard failures:
//! shedding is the server *working as designed* under pressure, and a
//! sweep that never sheds never found the saturation point.
//!
//! Outcome accounting is contention-free: counters are shared relaxed
//! atomics and latencies land in one [`obs::Histogram`] — no mutex on the
//! driver threads' hot path, no latency `Vec` to merge and sort at the
//! end. Percentiles follow the histogram's nearest-rank discipline, the
//! same methodology as the server side's stats.
//!
//! [`ErrorCode::Overloaded`]: crate::net::proto::ErrorCode::Overloaded

//! The **cluster scenario** ([`run_cluster`]) drives the same load at a
//! fabric router while killing (and optionally restarting) a backend at
//! pinned request counts — the hooks fire exactly once, on the driver
//! thread that crosses the threshold — then augments the report with the
//! router's failover counters fetched over the wire (`Stats` frame), so
//! a failover blip shows up as numbers, not anecdotes.

use crate::linalg::pool;
use crate::net::client::NetClient;
use crate::obs::Histogram;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// What to drive at the server.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Target address, `host:port`.
    pub addr: String,
    /// Concurrent connections (one scoped thread + one [`NetClient`]
    /// each).
    pub connections: usize,
    /// Requests each connection issues.
    pub requests_per_conn: usize,
    /// Model to request; `None` picks the first catalog entry.
    pub model: Option<String>,
    /// Rows per request (1 = single-image latency traffic; larger values
    /// exercise the batch path).
    pub batch: usize,
    /// Seed for the per-connection input generators.
    pub seed: u64,
}

impl LoadGenConfig {
    /// Defaults: 4 connections × 64 single-row requests, first model.
    pub fn new(addr: &str) -> LoadGenConfig {
        LoadGenConfig {
            addr: addr.to_string(),
            connections: 4,
            requests_per_conn: 64,
            model: None,
            batch: 1,
            seed: 1,
        }
    }
}

/// Aggregate outcome of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Connections driven.
    pub connections: usize,
    /// Requests actually issued over live connections.
    pub sent: usize,
    /// Requests answered with logits.
    pub ok: usize,
    /// Overload sheds: shed requests, plus one event per connection the
    /// server refused with an `Overloaded` handshake (those connections
    /// issue no requests, so `sent` excludes their quota).
    pub shed: usize,
    /// Failures: failed requests, plus one event per connection that
    /// could not be established for any non-overload reason.
    pub failed: usize,
    /// Wall-clock of the whole run, seconds.
    pub elapsed_s: f64,
    /// Median latency of successful requests, ms (log₂-histogram
    /// percentile, within one bucket width of the exact sample value).
    pub p50_ms: f32,
    /// 90th-percentile latency, ms.
    pub p90_ms: f32,
    /// 99th-percentile latency, ms.
    pub p99_ms: f32,
    /// Worst successful-request latency, ms (bucket upper edge).
    pub max_ms: f32,
}

impl LoadReport {
    /// Issued requests per second over the run's wall clock.
    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.sent as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests over {} conns in {:.2}s ({:.0} req/s): {} ok, {} shed, {} failed; \
             p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms max {:.2}ms",
            self.sent,
            self.connections,
            self.elapsed_s,
            self.req_per_s(),
            self.ok,
            self.shed,
            self.failed,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.max_ms,
        )
    }
}

/// Shared run-wide tallies: relaxed atomics + one latency histogram, so
/// driver threads never contend on a lock.
#[derive(Default)]
struct RunTallies {
    sent: AtomicU64,
    ok: AtomicU64,
    shed: AtomicU64,
    failed: AtomicU64,
    latency: Histogram,
}

/// A one-shot lifecycle hook: fires at most once, on whichever driver
/// thread crosses its request-count threshold first.
struct HookCell(Mutex<Option<Box<dyn FnOnce() + Send>>>);

impl HookCell {
    fn empty() -> HookCell {
        HookCell(Mutex::new(None))
    }
    fn some(f: impl FnOnce() + Send + 'static) -> HookCell {
        HookCell(Mutex::new(Some(Box::new(f))))
    }
    /// Fire if still armed; `true` the first time.
    fn fire(&self) -> bool {
        if let Some(f) = self.0.lock().unwrap().take() {
            f();
            true
        } else {
            false
        }
    }
    /// Still holding an unfired hook? (Does not fire it.)
    fn armed(&self) -> bool {
        self.0.lock().unwrap().is_some()
    }
}

/// Run one load generation pass against a live server.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    drive(cfg, None, None, &HookCell::empty(), &HookCell::empty())
}

/// Core driver shared by [`run`] and [`run_cluster`]: the hooks fire when
/// the run-wide sent counter crosses the matching threshold (`fetch_add`
/// hands every driver a unique count, so exactly one thread fires each).
fn drive(
    cfg: &LoadGenConfig,
    kill_at: Option<u64>,
    restart_at: Option<u64>,
    on_kill: &HookCell,
    on_restart: &HookCell,
) -> Result<LoadReport> {
    // resolve the target model (and its input dimension) from the
    // server's own catalog, via a probe connection
    let mut probe =
        NetClient::connect(&cfg.addr).map_err(|e| anyhow!("loadgen connect {}: {e}", cfg.addr))?;
    let catalog = probe.models().map_err(|e| anyhow!("loadgen handshake: {e}"))?;
    let entry = match &cfg.model {
        Some(name) => catalog
            .iter()
            .find(|m| &m.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = catalog.iter().map(|m| m.name.as_str()).collect();
                anyhow!("model '{name}' not served (catalog: {names:?})")
            })?
            .clone(),
        None => catalog
            .first()
            .ok_or_else(|| anyhow!("server serves no models"))?
            .clone(),
    };
    drop(probe);

    let connections = cfg.connections.max(1);
    let per_conn = cfg.requests_per_conn.max(1);
    let batch = cfg.batch.max(1);
    let in_dim = entry.in_dim as usize;
    let tallies = RunTallies::default();
    let t = Timer::start();
    // blocking drivers → scoped threads, never pool task slots
    pool::run_scoped(connections, |c| {
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE ^ ((c as u64) * 0x9E37_79B9));
        let mut input = vec![0.0f32; in_dim * batch];
        match NetClient::connect(&cfg.addr) {
            Ok(mut client) => {
                for _ in 0..per_conn {
                    rng.fill_normal(&mut input, 0.0, 1.0);
                    let rt = Timer::start();
                    let result = if batch == 1 {
                        client.infer(&entry.name, &input)
                    } else {
                        client.infer_batch(&entry.name, batch, &input)
                    };
                    let n = tallies.sent.fetch_add(1, Ordering::Relaxed) + 1;
                    if Some(n) == kill_at {
                        on_kill.fire();
                    }
                    if Some(n) == restart_at {
                        on_restart.fire();
                    }
                    match result {
                        Ok(_) => {
                            tallies.ok.fetch_add(1, Ordering::Relaxed);
                            tallies.latency.record_ns((rt.elapsed_s() * 1e9) as u64);
                        }
                        Err(e) if e.is_overloaded() => {
                            tallies.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            tallies.failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
            Err(e) => {
                // the connection never came up, so its quota was never
                // issued: `sent` stays 0 (keeping req/s honest — these
                // cost ~0 wall-clock) and the refusal is counted as ONE
                // connection-level event, shed when the server refused
                // it by design (Overloaded handshake), failed otherwise
                if e.is_overloaded() {
                    tallies.shed.fetch_add(1, Ordering::Relaxed);
                } else {
                    tallies.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    });
    let elapsed_s = t.elapsed_s();

    let lat = tallies.latency.snapshot();
    Ok(LoadReport {
        connections,
        sent: tallies.sent.load(Ordering::Relaxed) as usize,
        ok: tallies.ok.load(Ordering::Relaxed) as usize,
        shed: tallies.shed.load(Ordering::Relaxed) as usize,
        failed: tallies.failed.load(Ordering::Relaxed) as usize,
        elapsed_s,
        p50_ms: lat.percentile_ms(50.0),
        p90_ms: lat.percentile_ms(90.0),
        p99_ms: lat.percentile_ms(99.0),
        max_ms: lat.max_ms(),
    })
}

/// The cluster scenario: [`LoadGenConfig`] plus the request counts at
/// which to kill and (optionally) restart a backend mid-run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// The load to drive (typically at a fabric router).
    pub load: LoadGenConfig,
    /// Fire the kill hook when the run-wide sent count reaches this
    /// (`--kill-backend-at N` on the CLI). `None` = never.
    pub kill_at: Option<u64>,
    /// Fire the restart hook at this sent count. `None` = never.
    pub restart_at: Option<u64>,
}

/// Outcome of a [`run_cluster`] pass: the plain load report plus the
/// target's fabric counters (fetched over the wire after the run; `None`
/// when the target is not a router). Router counters are all-time, so
/// drive a fresh router per scenario for per-run numbers.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Shed/failure tallies and the latency tail, as in [`run`].
    pub load: LoadReport,
    /// Whether the kill hook fired.
    pub killed: bool,
    /// Whether the restart hook fired.
    pub restarted: bool,
    /// Router forward re-attempts (`fabric_retries`), if the target
    /// exposes fabric stats.
    pub router_retries: Option<u64>,
    /// Router backend switches (`fabric_failovers`).
    pub router_failovers: Option<u64>,
    /// Backend health transitions observed by the router.
    pub router_health_transitions: Option<u64>,
}

impl ClusterReport {
    /// One-line human summary (load line + fabric counters).
    pub fn summary(&self) -> String {
        let fabric = match (self.router_retries, self.router_failovers) {
            (Some(r), Some(f)) => format!(
                "; fabric: {r} retries, {f} failovers, {} health transitions",
                self.router_health_transitions.unwrap_or(0)
            ),
            _ => "; fabric: target exposes no fabric stats".to_string(),
        };
        format!(
            "{}{}{}{}",
            self.load.summary(),
            if self.killed { " [backend killed mid-run]" } else { "" },
            if self.restarted { " [backend restarted]" } else { "" },
            fabric
        )
    }
}

/// Run the cluster scenario: drive the load, kill a backend at
/// `kill_at` sent requests (the hook runs on the driver thread that
/// crosses the threshold), optionally restart it at `restart_at`, then
/// fetch the router's failover counters over the wire.
pub fn run_cluster(
    cfg: &ClusterConfig,
    on_kill: impl FnOnce() + Send + 'static,
    on_restart: impl FnOnce() + Send + 'static,
) -> Result<ClusterReport> {
    let kill = HookCell::some(on_kill);
    let restart = HookCell::some(on_restart);
    let load = drive(&cfg.load, cfg.kill_at, cfg.restart_at, &kill, &restart)?;
    // a hook that is no longer armed was consumed (fired) by the run
    let killed = cfg.kill_at.is_some() && !kill.armed();
    let restarted = cfg.restart_at.is_some() && !restart.armed();
    let fabric = fetch_fabric_stats(&cfg.load.addr);
    Ok(ClusterReport {
        load,
        killed,
        restarted,
        router_retries: fabric.map(|f| f.0),
        router_failovers: fabric.map(|f| f.1),
        router_health_transitions: fabric.map(|f| f.2),
    })
}

/// Ask the target for its stats frame and pull the router counters out,
/// if it is a fabric router (`{"router": {...}}` envelope).
fn fetch_fabric_stats(addr: &str) -> Option<(u64, u64, u64)> {
    let mut client = NetClient::connect(addr).ok()?;
    let json = client.stats().ok()?;
    let j = Json::parse(&json).ok()?;
    let r = j.get("router")?;
    Some((
        r.get("retries")?.as_f64()? as u64,
        r.get("failovers")?.as_f64()? as u64,
        r.get("health_transitions")?.as_f64()? as u64,
    ))
}
