//! Multi-connection load generator for LCQ-RPC servers: drive N blocking
//! connections at a target, count outcomes, report latency percentiles
//! and throughput.
//!
//! Connections are blocking request drivers, so they fan out on scoped
//! threads ([`crate::linalg::pool::run_scoped`]) and leave the worker
//! pool to the engine under test — the same discipline as the in-process
//! smoke clients. Overload sheds ([`ErrorCode::Overloaded`]
//! handshakes or error frames) are counted separately from hard failures:
//! shedding is the server *working as designed* under pressure, and a
//! sweep that never sheds never found the saturation point.
//!
//! Outcome accounting is contention-free: counters are shared relaxed
//! atomics and latencies land in one [`obs::Histogram`] — no mutex on the
//! driver threads' hot path, no latency `Vec` to merge and sort at the
//! end. Percentiles follow the histogram's nearest-rank discipline, the
//! same methodology as the server side's stats.
//!
//! [`ErrorCode::Overloaded`]: crate::net::proto::ErrorCode::Overloaded

use crate::linalg::pool;
use crate::net::client::NetClient;
use crate::obs::Histogram;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// What to drive at the server.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Target address, `host:port`.
    pub addr: String,
    /// Concurrent connections (one scoped thread + one [`NetClient`]
    /// each).
    pub connections: usize,
    /// Requests each connection issues.
    pub requests_per_conn: usize,
    /// Model to request; `None` picks the first catalog entry.
    pub model: Option<String>,
    /// Rows per request (1 = single-image latency traffic; larger values
    /// exercise the batch path).
    pub batch: usize,
    /// Seed for the per-connection input generators.
    pub seed: u64,
}

impl LoadGenConfig {
    /// Defaults: 4 connections × 64 single-row requests, first model.
    pub fn new(addr: &str) -> LoadGenConfig {
        LoadGenConfig {
            addr: addr.to_string(),
            connections: 4,
            requests_per_conn: 64,
            model: None,
            batch: 1,
            seed: 1,
        }
    }
}

/// Aggregate outcome of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Connections driven.
    pub connections: usize,
    /// Requests actually issued over live connections.
    pub sent: usize,
    /// Requests answered with logits.
    pub ok: usize,
    /// Overload sheds: shed requests, plus one event per connection the
    /// server refused with an `Overloaded` handshake (those connections
    /// issue no requests, so `sent` excludes their quota).
    pub shed: usize,
    /// Failures: failed requests, plus one event per connection that
    /// could not be established for any non-overload reason.
    pub failed: usize,
    /// Wall-clock of the whole run, seconds.
    pub elapsed_s: f64,
    /// Median latency of successful requests, ms (log₂-histogram
    /// percentile, within one bucket width of the exact sample value).
    pub p50_ms: f32,
    /// 90th-percentile latency, ms.
    pub p90_ms: f32,
    /// 99th-percentile latency, ms.
    pub p99_ms: f32,
    /// Worst successful-request latency, ms (bucket upper edge).
    pub max_ms: f32,
}

impl LoadReport {
    /// Issued requests per second over the run's wall clock.
    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.sent as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests over {} conns in {:.2}s ({:.0} req/s): {} ok, {} shed, {} failed; \
             p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms max {:.2}ms",
            self.sent,
            self.connections,
            self.elapsed_s,
            self.req_per_s(),
            self.ok,
            self.shed,
            self.failed,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.max_ms,
        )
    }
}

/// Shared run-wide tallies: relaxed atomics + one latency histogram, so
/// driver threads never contend on a lock.
#[derive(Default)]
struct RunTallies {
    sent: AtomicU64,
    ok: AtomicU64,
    shed: AtomicU64,
    failed: AtomicU64,
    latency: Histogram,
}

/// Run one load generation pass against a live server.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    // resolve the target model (and its input dimension) from the
    // server's own catalog, via a probe connection
    let mut probe =
        NetClient::connect(&cfg.addr).map_err(|e| anyhow!("loadgen connect {}: {e}", cfg.addr))?;
    let catalog = probe.models().map_err(|e| anyhow!("loadgen handshake: {e}"))?;
    let entry = match &cfg.model {
        Some(name) => catalog
            .iter()
            .find(|m| &m.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = catalog.iter().map(|m| m.name.as_str()).collect();
                anyhow!("model '{name}' not served (catalog: {names:?})")
            })?
            .clone(),
        None => catalog
            .first()
            .ok_or_else(|| anyhow!("server serves no models"))?
            .clone(),
    };
    drop(probe);

    let connections = cfg.connections.max(1);
    let per_conn = cfg.requests_per_conn.max(1);
    let batch = cfg.batch.max(1);
    let in_dim = entry.in_dim as usize;
    let tallies = RunTallies::default();
    let t = Timer::start();
    // blocking drivers → scoped threads, never pool task slots
    pool::run_scoped(connections, |c| {
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE ^ ((c as u64) * 0x9E37_79B9));
        let mut input = vec![0.0f32; in_dim * batch];
        match NetClient::connect(&cfg.addr) {
            Ok(mut client) => {
                for _ in 0..per_conn {
                    rng.fill_normal(&mut input, 0.0, 1.0);
                    let rt = Timer::start();
                    let result = if batch == 1 {
                        client.infer(&entry.name, &input)
                    } else {
                        client.infer_batch(&entry.name, batch, &input)
                    };
                    tallies.sent.fetch_add(1, Ordering::Relaxed);
                    match result {
                        Ok(_) => {
                            tallies.ok.fetch_add(1, Ordering::Relaxed);
                            tallies.latency.record_ns((rt.elapsed_s() * 1e9) as u64);
                        }
                        Err(e) if e.is_overloaded() => {
                            tallies.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            tallies.failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
            Err(e) => {
                // the connection never came up, so its quota was never
                // issued: `sent` stays 0 (keeping req/s honest — these
                // cost ~0 wall-clock) and the refusal is counted as ONE
                // connection-level event, shed when the server refused
                // it by design (Overloaded handshake), failed otherwise
                if e.is_overloaded() {
                    tallies.shed.fetch_add(1, Ordering::Relaxed);
                } else {
                    tallies.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    });
    let elapsed_s = t.elapsed_s();

    let lat = tallies.latency.snapshot();
    Ok(LoadReport {
        connections,
        sent: tallies.sent.load(Ordering::Relaxed) as usize,
        ok: tallies.ok.load(Ordering::Relaxed) as usize,
        shed: tallies.shed.load(Ordering::Relaxed) as usize,
        failed: tallies.failed.load(Ordering::Relaxed) as usize,
        elapsed_s,
        p50_ms: lat.percentile_ms(50.0),
        p90_ms: lat.percentile_ms(90.0),
        p99_ms: lat.percentile_ms(99.0),
        max_ms: lat.max_ms(),
    })
}
