//! Multi-connection load generator for LCQ-RPC servers: drive N blocking
//! connections at a target, count outcomes, report latency percentiles
//! and throughput.
//!
//! Connections are blocking request drivers, so they fan out on scoped
//! threads ([`crate::linalg::pool::run_scoped`]) and leave the worker
//! pool to the engine under test — the same discipline as the in-process
//! smoke clients. Overload sheds ([`ErrorCode::Overloaded`]
//! handshakes or error frames) are counted separately from hard failures:
//! shedding is the server *working as designed* under pressure, and a
//! sweep that never sheds never found the saturation point.
//!
//! Outcome accounting is contention-free: counters are shared relaxed
//! atomics and latencies land in one [`obs::Histogram`] — no mutex on the
//! driver threads' hot path, no latency `Vec` to merge and sort at the
//! end. Percentiles follow the histogram's nearest-rank discipline, the
//! same methodology as the server side's stats.
//!
//! [`ErrorCode::Overloaded`]: crate::net::proto::ErrorCode::Overloaded

//! The **cluster scenario** ([`run_cluster`]) drives the same load at a
//! fabric router while killing (and optionally restarting) a backend at
//! pinned request counts — the hooks fire exactly once, on the driver
//! thread that crosses the threshold — then augments the report with the
//! router's failover counters fetched over the wire (`Stats` frame), so
//! a failover blip shows up as numbers, not anecdotes.
//!
//! PR 9 adds the **open-loop scenarios** that pin the event-driven
//! connection plane's C10K behavior:
//!
//! * [`run_poisson`] — bursty open-loop arrivals: each connection draws
//!   exponential inter-arrival gaps from its seeded generator and fires
//!   a pipelined window per arrival, so offered load is set by the
//!   clock, not by the server's response rate.
//! * [`run_idle_army`] — thousands of mostly-idle connections held open
//!   by **one** holder thread (raw handshaken sockets, no thread per
//!   connection) while a few active drivers push pipelined traffic
//!   through the same plane; proves the fixed net-thread pool serves
//!   live traffic with an army camped on its poller.
//! * [`run_slow_loris`] — partial request frames trickled a byte at a
//!   time, then stalled; the plane's frame deadline (anchored at the
//!   *first* partial byte, so slow progress never resets it) must
//!   answer each with a typed `Timeout` error, never a hang.
//!
//! All three are seed-deterministic in their outcome *counts* (not
//! their timings), which is what the scenario tests pin.

use crate::linalg::pool;
use crate::net::client::{ClientError, NetClient};
use crate::net::proto::{self, ErrorCode, Frame, FrameReader, RequestFrame, WireError};
use crate::obs::Histogram;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

/// What to drive at the server.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Target address, `host:port`.
    pub addr: String,
    /// Concurrent connections (one scoped thread + one [`NetClient`]
    /// each).
    pub connections: usize,
    /// Requests each connection issues.
    pub requests_per_conn: usize,
    /// Model to request; `None` picks the first catalog entry.
    pub model: Option<String>,
    /// Rows per request (1 = single-image latency traffic; larger values
    /// exercise the batch path).
    pub batch: usize,
    /// Seed for the per-connection input generators.
    pub seed: u64,
    /// Request ids kept in flight per connection
    /// ([`NetClient::infer_pipelined`] window). `1` = classic
    /// request/response lockstep; larger values only apply to single-row
    /// traffic (`batch == 1`) and drive the server's pipelined path.
    pub pipeline: usize,
    /// Stamp every request with a client-origin trace context (v3
    /// servers only; ignored on a v2-negotiated connection). Each
    /// connection gets a disjoint trace-id base, and the report gains
    /// trace coverage: the fraction of issued trace ids found in the
    /// target's trace ring after the run.
    pub trace: bool,
}

impl LoadGenConfig {
    /// Defaults: 4 connections × 64 single-row requests, first model,
    /// no pipelining.
    pub fn new(addr: &str) -> LoadGenConfig {
        LoadGenConfig {
            addr: addr.to_string(),
            connections: 4,
            requests_per_conn: 64,
            model: None,
            batch: 1,
            seed: 1,
            pipeline: 1,
            trace: false,
        }
    }
}

/// Aggregate outcome of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Connections driven.
    pub connections: usize,
    /// Requests actually issued over live connections.
    pub sent: usize,
    /// Requests answered with logits.
    pub ok: usize,
    /// Overload sheds: shed requests, plus one event per connection the
    /// server refused with an `Overloaded` handshake (those connections
    /// issue no requests, so `sent` excludes their quota).
    pub shed: usize,
    /// Failures: failed requests, plus one event per connection that
    /// could not be established for any non-overload reason.
    pub failed: usize,
    /// Wall-clock of the whole run, seconds.
    pub elapsed_s: f64,
    /// Median latency of successful requests, ms (log₂-histogram
    /// percentile, within one bucket width of the exact sample value).
    pub p50_ms: f32,
    /// 90th-percentile latency, ms.
    pub p90_ms: f32,
    /// 99th-percentile latency, ms.
    pub p99_ms: f32,
    /// Worst successful-request latency, ms (bucket upper edge).
    pub max_ms: f32,
    /// Trace ids issued (0 unless [`LoadGenConfig::trace`] is on).
    pub trace_issued: usize,
    /// Issued trace ids found in the target's trace ring after the run
    /// (an overwrite-oldest ring: coverage below 1.0 means old traces
    /// were evicted — the signal for tuning `obs.trace_slots`).
    pub trace_found: usize,
}

impl LoadReport {
    /// Issued requests per second over the run's wall clock.
    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.sent as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Fraction of issued trace ids found in the target's trace ring
    /// (0.0 when tracing was off or nothing was issued).
    pub fn trace_coverage(&self) -> f64 {
        if self.trace_issued == 0 {
            0.0
        } else {
            self.trace_found as f64 / self.trace_issued as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let trace = if self.trace_issued > 0 {
            format!(
                "; trace coverage {}/{} ({:.0}%)",
                self.trace_found,
                self.trace_issued,
                100.0 * self.trace_coverage()
            )
        } else {
            String::new()
        };
        format!(
            "{} requests over {} conns in {:.2}s ({:.0} req/s): {} ok, {} shed, {} failed; \
             p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms max {:.2}ms{}",
            self.sent,
            self.connections,
            self.elapsed_s,
            self.req_per_s(),
            self.ok,
            self.shed,
            self.failed,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.max_ms,
            trace,
        )
    }
}

/// Shared run-wide tallies: relaxed atomics + one latency histogram, so
/// driver threads never contend on a lock.
#[derive(Default)]
struct RunTallies {
    sent: AtomicU64,
    ok: AtomicU64,
    shed: AtomicU64,
    failed: AtomicU64,
    latency: Histogram,
}

/// A one-shot lifecycle hook: fires at most once, on whichever driver
/// thread crosses its request-count threshold first.
struct HookCell(Mutex<Option<Box<dyn FnOnce() + Send>>>);

impl HookCell {
    fn empty() -> HookCell {
        HookCell(Mutex::new(None))
    }
    fn some(f: impl FnOnce() + Send + 'static) -> HookCell {
        HookCell(Mutex::new(Some(Box::new(f))))
    }
    /// Fire if still armed; `true` the first time.
    fn fire(&self) -> bool {
        if let Some(f) = self.0.lock().unwrap().take() {
            f();
            true
        } else {
            false
        }
    }
    /// Still holding an unfired hook? (Does not fire it.)
    fn armed(&self) -> bool {
        self.0.lock().unwrap().is_some()
    }
}

/// Run one load generation pass against a live server.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    drive(cfg, None, None, &HookCell::empty(), &HookCell::empty())
}

/// Core driver shared by [`run`] and [`run_cluster`]: the hooks fire when
/// the run-wide sent counter crosses the matching threshold (`fetch_add`
/// hands every driver a unique count, so exactly one thread fires each).
fn drive(
    cfg: &LoadGenConfig,
    kill_at: Option<u64>,
    restart_at: Option<u64>,
    on_kill: &HookCell,
    on_restart: &HookCell,
) -> Result<LoadReport> {
    let (model, in_dim) = resolve_model(&cfg.addr, cfg.model.as_deref())?;

    let connections = cfg.connections.max(1);
    let per_conn = cfg.requests_per_conn.max(1);
    let batch = cfg.batch.max(1);
    // pipelining drives single-row traffic; batch requests stay lockstep
    let window = if batch == 1 { cfg.pipeline.max(1) } else { 1 };
    let tallies = RunTallies::default();
    // per-connection (trace base, ids issued) pairs for the coverage
    // lookup after the run — bases are 2³² apart, so ids never collide
    let trace_spans: Mutex<Vec<(u64, u64)>> = Mutex::new(Vec::new());
    let t = Timer::start();
    // blocking drivers → scoped threads, never pool task slots
    pool::run_scoped(connections, |c| {
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE ^ ((c as u64) * 0x9E37_79B9));
        let mut input = vec![0.0f32; in_dim * batch.max(window)];
        match NetClient::connect(&cfg.addr) {
            Ok(mut client) => {
                let trace_base = ((c as u64 + 1) << 32) | (cfg.seed & 0xFFFF);
                if cfg.trace {
                    client.set_trace_base(trace_base);
                }
                let mut issued = 0usize;
                while issued < per_conn {
                    let w = window.min(per_conn - issued);
                    issued += w;
                    rng.fill_normal(&mut input[..in_dim * batch.max(w)], 0.0, 1.0);
                    let rt = Timer::start();
                    // one result per request: a window of pipelined
                    // single-row requests, or one (possibly batched)
                    // lockstep round trip
                    let results: Vec<Result<(), ClientError>> = if w > 1 {
                        let rows: Vec<&[f32]> = input[..in_dim * w].chunks(in_dim).collect();
                        client
                            .infer_pipelined(&model, &rows, w)
                            .into_iter()
                            .map(|r| r.map(|_| ()))
                            .collect()
                    } else if batch == 1 {
                        vec![client.infer(&model, &input[..in_dim]).map(|_| ())]
                    } else {
                        vec![client.infer_batch(&model, batch, &input).map(|_| ())]
                    };
                    let elapsed_ns = (rt.elapsed_s() * 1e9) as u64;
                    for result in results {
                        let n = tallies.sent.fetch_add(1, Ordering::Relaxed) + 1;
                        if Some(n) == kill_at {
                            on_kill.fire();
                        }
                        if Some(n) == restart_at {
                            on_restart.fire();
                        }
                        match result {
                            Ok(()) => {
                                tallies.ok.fetch_add(1, Ordering::Relaxed);
                                // pipelined slots share the window's
                                // round-trip wall clock
                                tallies.latency.record_ns(elapsed_ns);
                            }
                            Err(e) if e.is_overloaded() => {
                                tallies.shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                tallies.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if cfg.trace {
                    trace_spans.lock().unwrap().push((trace_base, client.traces_issued()));
                }
            }
            Err(e) => {
                // the connection never came up, so its quota was never
                // issued: `sent` stays 0 (keeping req/s honest — these
                // cost ~0 wall-clock) and the refusal is counted as ONE
                // connection-level event, shed when the server refused
                // it by design (Overloaded handshake), failed otherwise
                if e.is_overloaded() {
                    tallies.shed.fetch_add(1, Ordering::Relaxed);
                } else {
                    tallies.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    });
    let elapsed_s = t.elapsed_s();

    // coverage: how many of the trace ids we issued survive in the
    // target's (overwrite-oldest) trace ring
    let spans = trace_spans.into_inner().unwrap();
    let trace_issued: u64 = spans.iter().map(|&(_, n)| n).sum();
    let trace_found =
        if trace_issued > 0 { count_traces_in_target(&cfg.addr, &spans) } else { 0 };

    let lat = tallies.latency.snapshot();
    Ok(LoadReport {
        connections,
        sent: tallies.sent.load(Ordering::Relaxed) as usize,
        ok: tallies.ok.load(Ordering::Relaxed) as usize,
        shed: tallies.shed.load(Ordering::Relaxed) as usize,
        failed: tallies.failed.load(Ordering::Relaxed) as usize,
        elapsed_s,
        p50_ms: lat.percentile_ms(50.0),
        p90_ms: lat.percentile_ms(90.0),
        p99_ms: lat.percentile_ms(99.0),
        max_ms: lat.max_ms(),
        trace_issued: trace_issued as usize,
        trace_found,
    })
}

/// Fetch the target's stats document and count how many of our issued
/// trace ids (`base + 1 ..= base + n` per span) its `"trace_ids"` array
/// still holds. Any failure reads as zero coverage — the loadgen never
/// fails a run over a stats lookup.
fn count_traces_in_target(addr: &str, spans: &[(u64, u64)]) -> usize {
    let Ok(mut client) = NetClient::connect(addr) else { return 0 };
    let Ok(json) = client.stats() else { return 0 };
    let Ok(doc) = Json::parse(&json) else { return 0 };
    let Some(ids) = doc.get("trace_ids").and_then(|j| j.as_arr()) else { return 0 };
    let in_ring: std::collections::HashSet<u64> =
        ids.iter().filter_map(|j| j.as_f64()).map(|n| n as u64).collect();
    spans
        .iter()
        .map(|&(base, n)| (1..=n).filter(|i| in_ring.contains(&base.wrapping_add(*i))).count())
        .sum()
}

/// The cluster scenario: [`LoadGenConfig`] plus the request counts at
/// which to kill and (optionally) restart a backend mid-run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// The load to drive (typically at a fabric router).
    pub load: LoadGenConfig,
    /// Fire the kill hook when the run-wide sent count reaches this
    /// (`--kill-backend-at N` on the CLI). `None` = never.
    pub kill_at: Option<u64>,
    /// Fire the restart hook at this sent count. `None` = never.
    pub restart_at: Option<u64>,
}

/// Outcome of a [`run_cluster`] pass: the plain load report plus the
/// target's fabric counters (fetched over the wire after the run; `None`
/// when the target is not a router). Router counters are all-time, so
/// drive a fresh router per scenario for per-run numbers.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Shed/failure tallies and the latency tail, as in [`run`].
    pub load: LoadReport,
    /// Whether the kill hook fired.
    pub killed: bool,
    /// Whether the restart hook fired.
    pub restarted: bool,
    /// Router forward re-attempts (`fabric_retries`), if the target
    /// exposes fabric stats.
    pub router_retries: Option<u64>,
    /// Router backend switches (`fabric_failovers`).
    pub router_failovers: Option<u64>,
    /// Backend health transitions observed by the router.
    pub router_health_transitions: Option<u64>,
}

impl ClusterReport {
    /// One-line human summary (load line + fabric counters).
    pub fn summary(&self) -> String {
        let fabric = match (self.router_retries, self.router_failovers) {
            (Some(r), Some(f)) => format!(
                "; fabric: {r} retries, {f} failovers, {} health transitions",
                self.router_health_transitions.unwrap_or(0)
            ),
            _ => "; fabric: target exposes no fabric stats".to_string(),
        };
        format!(
            "{}{}{}{}",
            self.load.summary(),
            if self.killed { " [backend killed mid-run]" } else { "" },
            if self.restarted { " [backend restarted]" } else { "" },
            fabric
        )
    }
}

/// Run the cluster scenario: drive the load, kill a backend at
/// `kill_at` sent requests (the hook runs on the driver thread that
/// crosses the threshold), optionally restart it at `restart_at`, then
/// fetch the router's failover counters over the wire.
pub fn run_cluster(
    cfg: &ClusterConfig,
    on_kill: impl FnOnce() + Send + 'static,
    on_restart: impl FnOnce() + Send + 'static,
) -> Result<ClusterReport> {
    let kill = HookCell::some(on_kill);
    let restart = HookCell::some(on_restart);
    let load = drive(&cfg.load, cfg.kill_at, cfg.restart_at, &kill, &restart)?;
    // a hook that is no longer armed was consumed (fired) by the run
    let killed = cfg.kill_at.is_some() && !kill.armed();
    let restarted = cfg.restart_at.is_some() && !restart.armed();
    let fabric = fetch_fabric_stats(&cfg.load.addr);
    Ok(ClusterReport {
        load,
        killed,
        restarted,
        router_retries: fabric.map(|f| f.0),
        router_failovers: fabric.map(|f| f.1),
        router_health_transitions: fabric.map(|f| f.2),
    })
}

/// Ask the target for its stats frame and pull the router counters out,
/// if it is a fabric router (`{"router": {...}}` envelope).
fn fetch_fabric_stats(addr: &str) -> Option<(u64, u64, u64)> {
    let mut client = NetClient::connect(addr).ok()?;
    let json = client.stats().ok()?;
    let j = Json::parse(&json).ok()?;
    let r = j.get("router")?;
    Some((
        r.get("retries")?.as_f64()? as u64,
        r.get("failovers")?.as_f64()? as u64,
        r.get("health_transitions")?.as_f64()? as u64,
    ))
}

/// Resolve the target model name and input dimension from the server's
/// own catalog, via a probe connection (closed before the run starts).
fn resolve_model(addr: &str, want: Option<&str>) -> Result<(String, usize)> {
    let mut probe = NetClient::connect(addr).map_err(|e| anyhow!("loadgen connect {addr}: {e}"))?;
    let catalog = probe.models().map_err(|e| anyhow!("loadgen handshake: {e}"))?;
    let entry = match want {
        Some(name) => catalog.iter().find(|m| m.name == name).ok_or_else(|| {
            let names: Vec<&str> = catalog.iter().map(|m| m.name.as_str()).collect();
            anyhow!("model '{name}' not served (catalog: {names:?})")
        })?,
        None => catalog.first().ok_or_else(|| anyhow!("server serves no models"))?,
    };
    Ok((entry.name.clone(), entry.in_dim as usize))
}

// ---------------------------------------------------------------------------
// open-loop scenarios (PR 9)
// ---------------------------------------------------------------------------

/// Open-loop Poisson-burst arrivals: each connection draws exponential
/// inter-arrival gaps at `rate_hz` and fires a window of
/// `load.pipeline` pipelined single-row requests per arrival.
#[derive(Clone, Debug)]
pub struct PoissonConfig {
    /// Target, connection count, model, seed and pipeline window.
    /// `requests_per_conn` and `batch` are ignored (arrivals are bursts
    /// of single-row requests).
    pub load: LoadGenConfig,
    /// Mean arrival rate per connection, bursts per second. Gaps are
    /// clamped to 250 ms so a pathological draw cannot stall a run.
    pub rate_hz: f64,
    /// Bursts each connection fires.
    pub bursts: usize,
}

impl PoissonConfig {
    /// Defaults: 4 connections × 16 bursts of 4 pipelined requests at a
    /// mean 200 bursts/s per connection.
    pub fn new(addr: &str) -> PoissonConfig {
        let mut load = LoadGenConfig::new(addr);
        load.pipeline = 4;
        PoissonConfig { load, rate_hz: 200.0, bursts: 16 }
    }
}

/// Run the Poisson-burst scenario. The report's `sent` is exactly
/// `connections × bursts × pipeline` whenever every connection comes up
/// (arrival *times* vary; offered request *counts* do not).
pub fn run_poisson(cfg: &PoissonConfig) -> Result<LoadReport> {
    let (model, in_dim) = resolve_model(&cfg.load.addr, cfg.load.model.as_deref())?;
    let connections = cfg.load.connections.max(1);
    let bursts = cfg.bursts.max(1);
    let window = cfg.load.pipeline.max(1);
    let rate = if cfg.rate_hz > 0.0 { cfg.rate_hz } else { 200.0 };
    let tallies = RunTallies::default();
    let t = Timer::start();
    pool::run_scoped(connections, |c| {
        let mut rng = Rng::new(cfg.load.seed ^ 0xC0DE ^ ((c as u64) * 0x9E37_79B9));
        let mut input = vec![0.0f32; in_dim * window];
        match NetClient::connect(&cfg.load.addr) {
            Ok(mut client) => {
                for _ in 0..bursts {
                    // exponential inter-arrival gap: the arrival clock is
                    // independent of the server's response rate
                    let gap_s = (-(1.0 - rng.uniform()).ln() / rate).min(0.25);
                    thread::sleep(Duration::from_secs_f64(gap_s));
                    rng.fill_normal(&mut input, 0.0, 1.0);
                    let rows: Vec<&[f32]> = input.chunks(in_dim).collect();
                    let rt = Timer::start();
                    let results = client.infer_pipelined(&model, &rows, window);
                    let elapsed_ns = (rt.elapsed_s() * 1e9) as u64;
                    for result in results {
                        tallies.sent.fetch_add(1, Ordering::Relaxed);
                        match result {
                            Ok(_) => {
                                tallies.ok.fetch_add(1, Ordering::Relaxed);
                                tallies.latency.record_ns(elapsed_ns);
                            }
                            Err(e) if e.is_overloaded() => {
                                tallies.shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                tallies.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            Err(e) if e.is_overloaded() => {
                tallies.shed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                tallies.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
    let elapsed_s = t.elapsed_s();
    let lat = tallies.latency.snapshot();
    Ok(LoadReport {
        connections,
        sent: tallies.sent.load(Ordering::Relaxed) as usize,
        ok: tallies.ok.load(Ordering::Relaxed) as usize,
        shed: tallies.shed.load(Ordering::Relaxed) as usize,
        failed: tallies.failed.load(Ordering::Relaxed) as usize,
        elapsed_s,
        p50_ms: lat.percentile_ms(50.0),
        p90_ms: lat.percentile_ms(90.0),
        p99_ms: lat.percentile_ms(99.0),
        max_ms: lat.max_ms(),
        trace_issued: 0,
        trace_found: 0,
    })
}

/// The idle-army scenario: a herd of mostly-idle connections camped on
/// the server's pollers while a few active drivers push traffic.
#[derive(Clone, Debug)]
pub struct IdleArmyConfig {
    /// Target address, `host:port`.
    pub addr: String,
    /// Idle herd size. One holder thread raw-handshakes each socket
    /// sequentially and keeps **all of them** open until the active
    /// drivers finish — no thread-per-connection, so thousands are
    /// cheap.
    pub connections: usize,
    /// Active traffic connections (one scoped thread + [`NetClient`]
    /// each). They wait for the whole herd to be camped before issuing
    /// their first request. `0` = pure camp: the herd is held only
    /// until the last handshake lands, then released.
    pub active: usize,
    /// Requests each active connection issues.
    pub requests_per_active: usize,
    /// Model for the active traffic; `None` picks the first catalog
    /// entry.
    pub model: Option<String>,
    /// Pipeline window for the active traffic.
    pub pipeline: usize,
    /// Seed for the active drivers' input generators.
    pub seed: u64,
    /// Per-socket cap on waiting for the server's hello. A herd socket
    /// that exceeds it counts as `idle_failed`, never blocks the run.
    pub handshake_timeout: Duration,
}

impl IdleArmyConfig {
    /// Defaults: 64-strong herd, 4 active drivers × 16 requests
    /// pipelined 4-deep.
    pub fn new(addr: &str) -> IdleArmyConfig {
        IdleArmyConfig {
            addr: addr.to_string(),
            connections: 64,
            active: 4,
            requests_per_active: 16,
            model: None,
            pipeline: 4,
            seed: 1,
            handshake_timeout: Duration::from_secs(5),
        }
    }
}

/// Outcome of [`run_idle_army`]: herd bookkeeping plus the active
/// drivers' load tallies.
#[derive(Clone, Debug)]
pub struct IdleArmyReport {
    /// Herd size asked for.
    pub idle_connections: usize,
    /// Herd sockets that handshook and stayed camped to the end.
    pub idle_held: usize,
    /// Herd sockets the server refused by design (`Overloaded`
    /// handshake at the door).
    pub idle_refused: usize,
    /// Herd sockets that failed for any other reason (connect error,
    /// handshake timeout, unexpected frame).
    pub idle_failed: usize,
    /// Active requests issued.
    pub sent: usize,
    /// Active requests answered with logits.
    pub ok: usize,
    /// Active requests shed with a typed `Overloaded`.
    pub shed: usize,
    /// Active requests failed.
    pub failed: usize,
    /// Wall-clock of the whole run, seconds.
    pub elapsed_s: f64,
}

impl IdleArmyReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "idle army: {}/{} camped ({} refused, {} failed); active traffic: \
             {} sent, {} ok, {} shed, {} failed in {:.2}s",
            self.idle_held,
            self.idle_connections,
            self.idle_refused,
            self.idle_failed,
            self.sent,
            self.ok,
            self.shed,
            self.failed,
            self.elapsed_s,
        )
    }
}

/// One raw-socket handshake outcome for the idle herd.
enum RawHandshake {
    Open(TcpStream),
    Refused,
    Failed,
}

/// Handshake a bare socket: send the client preamble, read the server's
/// preamble and its first frame. `Hello` = open, a typed `Overloaded`
/// error = refused at the door, anything else (including `timeout`
/// elapsing) = failed.
fn raw_handshake(addr: &str, timeout: Duration) -> RawHandshake {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return RawHandshake::Failed,
    };
    let timeout = timeout.max(Duration::from_millis(10));
    if stream.set_read_timeout(Some(timeout)).is_err() {
        return RawHandshake::Failed;
    }
    if stream.write_all(&proto::encode_preamble()).is_err() {
        return RawHandshake::Failed;
    }
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    if stream.read_exact(&mut pre).is_err() || proto::decode_preamble(&pre).is_err() {
        return RawHandshake::Failed;
    }
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    let deadline = Instant::now() + timeout;
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return RawHandshake::Open(stream),
            Ok(Some(Frame::Error(e))) if e.code == ErrorCode::Overloaded => {
                return RawHandshake::Refused
            }
            Ok(Some(_)) => return RawHandshake::Failed,
            Ok(None) => {
                if Instant::now() >= deadline {
                    return RawHandshake::Failed;
                }
            }
            Err(_) => return RawHandshake::Failed,
        }
    }
}

/// Run the idle-army scenario. Sequencing: the holder thread camps the
/// whole herd first; the active drivers wait for it, run their traffic,
/// and the last one to finish releases the herd. Every count in the
/// report is deterministic for a fixed config against an unloaded
/// server with capacity for the herd.
pub fn run_idle_army(cfg: &IdleArmyConfig) -> Result<IdleArmyReport> {
    let active = cfg.active;
    let resolved = if active > 0 {
        Some(resolve_model(&cfg.addr, cfg.model.as_deref())?)
    } else {
        None
    };
    let herd = cfg.connections;
    let window = cfg.pipeline.max(1);
    let per_active = cfg.requests_per_active.max(1);

    let herd_up = AtomicBool::new(false);
    let release = AtomicBool::new(active == 0);
    let actives_done = AtomicUsize::new(0);
    let idle_held = AtomicUsize::new(0);
    let idle_refused = AtomicUsize::new(0);
    let idle_failed = AtomicUsize::new(0);
    let tallies = RunTallies::default();
    let t = Timer::start();

    // thread 0 is the herd holder; threads 1..=active drive traffic
    pool::run_scoped(active + 1, |i| {
        if i == 0 {
            let mut held: Vec<TcpStream> = Vec::with_capacity(herd);
            for _ in 0..herd {
                match raw_handshake(&cfg.addr, cfg.handshake_timeout) {
                    RawHandshake::Open(s) => held.push(s),
                    RawHandshake::Refused => {
                        idle_refused.fetch_add(1, Ordering::Relaxed);
                    }
                    RawHandshake::Failed => {
                        idle_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            idle_held.store(held.len(), Ordering::Relaxed);
            herd_up.store(true, Ordering::Release);
            while !release.load(Ordering::Acquire) {
                thread::sleep(Duration::from_millis(1));
            }
            drop(held); // the army decamps only after the traffic is done
            return;
        }

        // active driver: wait until the army is camped, then drive
        while !herd_up.load(Ordering::Acquire) {
            thread::sleep(Duration::from_millis(1));
        }
        let (model, in_dim) = resolved.as_ref().expect("active > 0 resolved a model");
        let in_dim = *in_dim;
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE ^ (((i - 1) as u64) * 0x9E37_79B9));
        let mut input = vec![0.0f32; in_dim * window];
        match NetClient::connect(&cfg.addr) {
            Ok(mut client) => {
                let mut issued = 0usize;
                while issued < per_active {
                    let w = window.min(per_active - issued);
                    issued += w;
                    rng.fill_normal(&mut input[..in_dim * w], 0.0, 1.0);
                    let rows: Vec<&[f32]> = input[..in_dim * w].chunks(in_dim).collect();
                    for result in client.infer_pipelined(model, &rows, w) {
                        tallies.sent.fetch_add(1, Ordering::Relaxed);
                        match result {
                            Ok(_) => {
                                tallies.ok.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) if e.is_overloaded() => {
                                tallies.shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                tallies.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            Err(e) if e.is_overloaded() => {
                tallies.shed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                tallies.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        // last driver out releases the herd — every exit path lands here
        if actives_done.fetch_add(1, Ordering::AcqRel) + 1 == active {
            release.store(true, Ordering::Release);
        }
    });

    Ok(IdleArmyReport {
        idle_connections: herd,
        idle_held: idle_held.load(Ordering::Relaxed),
        idle_refused: idle_refused.load(Ordering::Relaxed),
        idle_failed: idle_failed.load(Ordering::Relaxed),
        sent: tallies.sent.load(Ordering::Relaxed) as usize,
        ok: tallies.ok.load(Ordering::Relaxed) as usize,
        shed: tallies.shed.load(Ordering::Relaxed) as usize,
        failed: tallies.failed.load(Ordering::Relaxed) as usize,
        elapsed_s: t.elapsed_s(),
    })
}

/// The slow-loris scenario: trickle a valid request frame a byte at a
/// time, then stall mid-frame and wait for the server's verdict.
#[derive(Clone, Debug)]
pub struct SlowLorisConfig {
    /// Target address, `host:port`.
    pub addr: String,
    /// Loris connections (one scoped thread each).
    pub connections: usize,
    /// Frame-prefix bytes trickled after the handshake. Clamped so the
    /// frame is **never** completed; the run always ends with a stalled
    /// partial frame on the server.
    pub trickle_bytes: usize,
    /// Pause between trickled bytes.
    pub gap: Duration,
    /// How long to wait for the server's `Timeout` verdict after the
    /// stall before declaring the connection hung.
    pub response_timeout: Duration,
}

impl SlowLorisConfig {
    /// Defaults: 4 lorises trickling 6 bytes, 10 ms apart, 10 s verdict
    /// window.
    pub fn new(addr: &str) -> SlowLorisConfig {
        SlowLorisConfig {
            addr: addr.to_string(),
            connections: 4,
            trickle_bytes: 6,
            gap: Duration::from_millis(10),
            response_timeout: Duration::from_secs(10),
        }
    }
}

/// Outcome of [`run_slow_loris`]: how every loris connection ended.
/// Against a healthy plane, `timed_out == connections` exactly — a
/// typed verdict for every attack, never a hang.
#[derive(Clone, Debug)]
pub struct SlowLorisReport {
    /// Loris connections driven.
    pub connections: usize,
    /// Connections answered with a typed `Timeout` error frame.
    pub timed_out: usize,
    /// Connections the server closed without any error frame.
    pub closed_unanswered: usize,
    /// Connections that failed some other way — including still hanging
    /// when `response_timeout` elapsed, the one outcome a correct plane
    /// never produces.
    pub failed: usize,
    /// Wall-clock of the whole run, seconds.
    pub elapsed_s: f64,
}

impl SlowLorisReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "slow-loris: {} connections → {} timed out (typed), {} closed unanswered, \
             {} failed/hung in {:.2}s",
            self.connections, self.timed_out, self.closed_unanswered, self.failed, self.elapsed_s,
        )
    }
}

/// Run the slow-loris scenario against a live server or router.
pub fn run_slow_loris(cfg: &SlowLorisConfig) -> Result<SlowLorisReport> {
    let connections = cfg.connections.max(1);
    // a plausible frame to trickle a prefix of: the bytes are valid
    // LCQ-RPC right up to the stall, so this is indistinguishable from a
    // slow legitimate client — which is exactly the attack
    let frame = Frame::Request(RequestFrame {
        id: 1,
        model: "slow-loris".to_string(),
        rows: 1,
        cols: 16,
        data: vec![0.0; 16],
        trace: None,
    })
    .to_bytes();
    let trickle = cfg.trickle_bytes.clamp(1, frame.len() - 1);
    let timed_out = AtomicUsize::new(0);
    let closed_unanswered = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let t = Timer::start();
    pool::run_scoped(connections, |_| {
        match loris_once(cfg, &frame[..trickle]) {
            LorisOutcome::TimedOut => timed_out.fetch_add(1, Ordering::Relaxed),
            LorisOutcome::ClosedUnanswered => closed_unanswered.fetch_add(1, Ordering::Relaxed),
            LorisOutcome::Failed => failed.fetch_add(1, Ordering::Relaxed),
        };
    });
    Ok(SlowLorisReport {
        connections,
        timed_out: timed_out.load(Ordering::Relaxed),
        closed_unanswered: closed_unanswered.load(Ordering::Relaxed),
        failed: failed.load(Ordering::Relaxed),
        elapsed_s: t.elapsed_s(),
    })
}

enum LorisOutcome {
    TimedOut,
    ClosedUnanswered,
    Failed,
}

/// One loris connection: handshake, trickle `prefix` a byte at a time,
/// stall, then read until the server's verdict (skipping the hello).
fn loris_once(cfg: &SlowLorisConfig, prefix: &[u8]) -> LorisOutcome {
    let mut stream = match TcpStream::connect(&cfg.addr) {
        Ok(s) => s,
        Err(_) => return LorisOutcome::Failed,
    };
    if stream.set_read_timeout(Some(Duration::from_millis(50))).is_err() {
        return LorisOutcome::Failed;
    }
    if stream.write_all(&proto::encode_preamble()).is_err() {
        return LorisOutcome::Failed;
    }
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    if stream.read_exact(&mut pre).is_err() || proto::decode_preamble(&pre).is_err() {
        return LorisOutcome::Failed;
    }
    // the trickle: one byte per gap, never the whole frame
    for b in prefix {
        if stream.write_all(std::slice::from_ref(b)).is_err() {
            // the server already gave up on us — go read its verdict
            break;
        }
        thread::sleep(cfg.gap);
    }
    // the stall: wait for the typed verdict
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    let deadline = Instant::now() + cfg.response_timeout;
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => {} // handshake hello, not the verdict
            Ok(Some(Frame::Error(e))) if e.code == ErrorCode::Timeout => {
                return LorisOutcome::TimedOut
            }
            Ok(Some(_)) => return LorisOutcome::Failed,
            Ok(None) => {
                if Instant::now() >= deadline {
                    return LorisOutcome::Failed; // the one forbidden outcome: a hang
                }
            }
            Err(WireError::Closed) => return LorisOutcome::ClosedUnanswered,
            Err(_) => return LorisOutcome::Failed,
        }
    }
}
