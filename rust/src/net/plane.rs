//! The event-driven connection plane shared by [`crate::net::server`] and
//! [`crate::net::router`]: one acceptor plus a small fixed pool of net
//! threads, each running an epoll readiness loop
//! ([`crate::util::epoll::Poller`]) over thousands of non-blocking
//! sockets.
//!
//! The old plane was thread-per-connection: `max_connections` blocking
//! handler threads, one request in flight per socket. This module
//! multiplexes instead (diagram in `docs/ARCHITECTURE.md`):
//!
//! * the **acceptor** owns the (non-blocking) listener and a bounded
//!   unserviced backlog; live connections are handed round-robin to the
//!   net threads, and beyond `max_connections + backlog` the door shed
//!   (`Overloaded` handshake) is explicit, exactly as before;
//! * each **net thread** owns a [`Poller`], a slab of connection states
//!   (generation-tagged tokens, so a stale completion can never write
//!   into a recycled slot), and a completion inbox. Per connection it
//!   keeps the existing [`FrameReader`] partial-frame state — framing
//!   survives arbitrary split points — plus a **bounded write queue**:
//!   replies are queued and flushed on writability, and a request that
//!   arrives while `pending + queued ≥ max_inflight` is shed typed
//!   (`Overloaded`, counted in `net_writeq_sheds`) instead of buffering
//!   without bound;
//! * requests leave the net thread immediately: the [`Dispatch`] owner
//!   either answers inline (validation errors, sheds) or routes the work
//!   (batch executors, forward workers) and later posts a [`Completion`]
//!   through a [`CompletionSink`], which wakes the owning poller. Net
//!   threads never block on compute — that is what lets a handful of
//!   them carry a C10K connection count.
//!
//! Deadlines are scanned on the poll tick: the handshake window, the
//! per-frame progress deadline (slow-loris, typed `Timeout` shed), and a
//! write-stall window for peers that stop reading their replies.

use crate::net::proto::{
    self, ErrorCode, ErrorFrame, Frame, FrameReader, RequestFrame, StatsResponseFrame, WireError,
};
use crate::obs::{self, CounterId, GaugeId, HistId, Stage, Trace};
use crate::util::epoll::{raw_fd, Event, Interest, Poller, RawFd, Waker};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Poll-loop tick: upper bound on how long a net thread sleeps before
/// re-checking shutdown and connection deadlines.
const POLL_TICK: Duration = Duration::from_millis(10);

/// Acceptor sleep between empty non-blocking `accept` sweeps.
const ACCEPT_TICK: Duration = Duration::from_millis(5);

/// A connection whose write queue makes no byte progress for this long
/// (peer stopped reading) is dropped — queued replies must drain or die.
const WRITE_STALL: Duration = Duration::from_secs(5);

/// Deadline for the pre-hello phase: a connection that has not delivered
/// its preamble within this window is dropped, so silent connects cannot
/// occupy slots forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Resolved knobs the plane runs with (derived from `NetConfig`).
#[derive(Clone)]
pub(crate) struct PlaneConfig {
    /// Thread-name prefix (`lcq-net`, `lcq-router`).
    pub name: &'static str,
    /// Connection slots across all net threads; beyond this plus a
    /// same-sized backlog, connections are shed at the door.
    pub max_connections: usize,
    /// Net (event-loop) threads.
    pub net_threads: usize,
    /// Per-connection pipeline bound: in-flight requests plus queued
    /// reply frames. The write-queue backpressure limit.
    pub max_inflight: usize,
    /// Largest accepted frame payload, bytes.
    pub max_frame: usize,
    /// Per-frame progress deadline (slow-loris shed).
    pub frame_deadline: Duration,
    /// Shared per-thread plane books (wakeups, writeq depth), rendered in
    /// the owning dispatcher's snapshot.
    pub stats: Arc<PlaneStats>,
}

/// Per-net-thread plane books: exact per-instance counts (the rule every
/// serving stat follows — see `obs` module docs) exposed through the
/// owning dispatcher's snapshot so `obs.trace_slots` and thread counts
/// are tunable from observed numbers, not guesswork.
pub(crate) struct PlaneStats {
    /// Poll-loop iterations that delivered work, per net thread.
    wakeups: Vec<AtomicU64>,
    /// Replies queued in write queues at the last poll tick, per thread.
    writeq_depth: Vec<AtomicU64>,
}

impl PlaneStats {
    /// Zeroed books for `net_threads` threads.
    pub fn new(net_threads: usize) -> PlaneStats {
        let n = net_threads.max(1);
        PlaneStats {
            wakeups: (0..n).map(|_| AtomicU64::new(0)).collect(),
            writeq_depth: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Sum of the per-thread writeq depths stored at the last poll ticks.
    pub fn total_writeq_depth(&self) -> u64 {
        self.writeq_depth.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Sum of per-thread wakeup counts.
    pub fn total_wakeups(&self) -> u64 {
        self.wakeups.iter().map(|w| w.load(Ordering::Relaxed)).sum()
    }

    /// Snapshot object: `{"net_threads": n, "wakeups": [...],
    /// "writeq_depth": [...]}` (arrays indexed by net thread).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("net_threads", Json::from(self.wakeups.len())),
            (
                "wakeups",
                Json::Arr(
                    self.wakeups
                        .iter()
                        .map(|w| Json::from(w.load(Ordering::Relaxed) as usize))
                        .collect(),
                ),
            ),
            (
                "writeq_depth",
                Json::Arr(
                    self.writeq_depth
                        .iter()
                        .map(|d| Json::from(d.load(Ordering::Relaxed) as usize))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Identifies one live connection: slab slot plus generation. Stale keys
/// (connection closed and slot recycled) are detected and dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct ConnKey {
    pub slot: u32,
    pub gen: u32,
}

/// Per-request context handed to [`Dispatch::on_request`].
#[derive(Clone, Copy)]
pub(crate) struct RequestCtx {
    /// The connection the reply must go back to.
    pub key: ConnKey,
    /// Handshake span of this connection, ns (shared by its requests).
    pub accept_ns: u64,
    /// Frame decode CPU time for this request, ns.
    pub decode_ns: u64,
}

/// Stage spans a dispatcher measured off the net thread; the plane adds
/// the write span and publishes the trace via [`Dispatch::record_trace`].
pub(crate) struct TraceDraft {
    pub id: u64,
    /// Fleet-wide trace id propagated on the wire (0 = untraced).
    pub trace_id: u64,
    pub accept_ns: u64,
    pub decode_ns: u64,
    pub queue_ns: u64,
    pub assembly_ns: u64,
    pub compute_ns: u64,
    pub frame_ns: u64,
}

/// A finished asynchronous request: encoded reply bytes routed back to
/// the owning net thread.
pub(crate) struct Completion {
    pub key: ConnKey,
    pub bytes: Vec<u8>,
    /// Present on successful responses when tracing is enabled.
    pub trace: Option<TraceDraft>,
}

/// Cloneable route for [`Completion`]s into one net thread: an unbounded
/// channel send plus a poller wake. Safe to call from any thread (serve
/// executors, forward workers); if the net thread is gone the completion
/// is silently dropped — the connection it addressed is gone too.
#[derive(Clone)]
pub(crate) struct CompletionSink {
    tx: Sender<Completion>,
    waker: Waker,
}

impl CompletionSink {
    /// Post one completion and wake the owning poller.
    pub fn send(&self, completion: Completion) {
        if self.tx.send(completion).is_ok() {
            self.waker.wake();
        }
    }
}

/// What [`Dispatch::on_request`] decided.
pub(crate) enum RequestAction {
    /// Answer now with these encoded frame bytes (validation errors,
    /// sheds); does not count against the connection's pipeline bound.
    Reply(Vec<u8>),
    /// The request was admitted and will answer through the sink; the
    /// plane counts it in-flight until its [`Completion`] arrives.
    Async,
}

/// Counter-relevant plane events, mapped by the dispatcher onto its own
/// per-instance stats (and their global mirrors).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PlaneEvent {
    /// A connection was accepted by the listener.
    Connection,
    /// A connection was shed at the door (slots + backlog full).
    ConnectionShed,
    /// A connection was shed by the per-frame progress deadline.
    FrameTimeout,
    /// A stats snapshot frame was served.
    StatsServed,
    /// A fleet-stats frame was answered (routers only).
    FleetStatsServed,
    /// A request was shed by the per-connection pipeline bound.
    WriteqShed,
}

/// The protocol owner plugged into the plane: the net server (micro-batch
/// engine behind it) or the router (serve fabric behind it).
pub(crate) trait Dispatch: Send + Sync + 'static {
    /// Server preamble + hello frame for a freshly handshaken connection.
    fn hello_bytes(&self) -> Vec<u8>;
    /// Handle one decoded request: reply inline or admit it and answer
    /// later through `sink`.
    fn on_request(&self, rctx: RequestCtx, req: RequestFrame, sink: &CompletionSink)
        -> RequestAction;
    /// The stats snapshot document served for `StatsRequest` frames.
    fn snapshot_json(&self) -> String;
    /// Map a plane event onto the dispatcher's counters.
    fn event(&self, ev: PlaneEvent);
    /// Detail line for door sheds (`Overloaded` handshake).
    fn shed_message(&self) -> String;
    /// Detail line for the `ShuttingDown` notice open connections get at
    /// plane stop.
    fn shutdown_message(&self) -> String {
        "server shutting down".to_string()
    }
    /// Publish one finished request trace (servers keep a ring; the
    /// router has per-request fabric histograms instead).
    fn record_trace(&self, _trace: &Trace) {}
    /// Answer a `FleetStatsRequest`. The default (`None`) rejects the
    /// frame as `Malformed` and closes — backends do not speak fleet
    /// aggregation; only the fabric router overrides this.
    fn on_fleet_stats(
        &self,
        _key: ConnKey,
        _id: u64,
        _sink: &CompletionSink,
    ) -> Option<RequestAction> {
        None
    }
}

/// Shared liveness state between the acceptor, the net threads and
/// [`Plane::stop`].
struct Shared {
    shutdown: AtomicBool,
    /// Connections currently owned by net threads (dispatched and not
    /// yet closed); the acceptor's admission gate.
    active: AtomicUsize,
}

/// The running plane: acceptor + net threads. Stop (idempotent) sets the
/// flag, wakes every poller, and joins.
pub(crate) struct Plane {
    shared: Arc<Shared>,
    wakers: Vec<Waker>,
    acceptor: Option<JoinHandle<()>>,
    io_threads: Vec<JoinHandle<()>>,
}

impl Plane {
    /// Spawn the net threads and the acceptor over a bound listener.
    /// Fails cleanly (no threads leaked) if readiness polling is
    /// unavailable on this platform.
    pub fn start(
        listener: TcpListener,
        dispatch: Arc<dyn Dispatch>,
        cfg: PlaneConfig,
    ) -> Result<Plane> {
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let n_threads = cfg.net_threads.max(1);
        let shared = Arc::new(Shared {
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        });
        let mut pollers = Vec::with_capacity(n_threads);
        for _ in 0..n_threads {
            pollers.push(Poller::new().context("creating readiness poller")?);
        }
        let wakers: Vec<Waker> = pollers.iter().map(|p| p.waker()).collect();
        let inboxes: Vec<Arc<Mutex<VecDeque<TcpStream>>>> =
            (0..n_threads).map(|_| Arc::new(Mutex::new(VecDeque::new()))).collect();
        let mut io_threads = Vec::with_capacity(n_threads);
        for (i, poller) in pollers.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Completion>();
            let sink = CompletionSink { tx, waker: poller.waker() };
            let mut io = IoThread {
                poller,
                dispatch: Arc::clone(&dispatch),
                shared: Arc::clone(&shared),
                cfg: cfg.clone(),
                sink,
                index: i,
                conns: Vec::new(),
                gens: Vec::new(),
                free: Vec::new(),
            };
            let inbox = Arc::clone(&inboxes[i]);
            let handle = std::thread::Builder::new()
                .name(format!("{}-io{i}", cfg.name))
                .spawn(move || io.run(inbox, rx))
                .context("spawning net thread")?;
            io_threads.push(handle);
        }
        let acceptor = {
            let shared = Arc::clone(&shared);
            let dispatch = Arc::clone(&dispatch);
            let wakers = wakers.clone();
            let max_conns = cfg.max_connections.max(1);
            std::thread::Builder::new()
                .name(format!("{}-accept", cfg.name))
                .spawn(move || acceptor_loop(listener, shared, dispatch, inboxes, wakers, max_conns))
                .context("spawning acceptor")?
        };
        Ok(Plane { shared, wakers, acceptor: Some(acceptor), io_threads })
    }

    /// Stop accepting, wake and join every net thread (open connections
    /// get a best-effort `ShuttingDown` notice and are closed).
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w.wake();
        }
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.io_threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Plane {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Accept loop: non-blocking accept sweeps, an admission gate on the
/// global active count, a bounded unserviced backlog, and explicit door
/// sheds beyond it.
fn acceptor_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    dispatch: Arc<dyn Dispatch>,
    inboxes: Vec<Arc<Mutex<VecDeque<TcpStream>>>>,
    wakers: Vec<Waker>,
    max_conns: usize,
) {
    // Parked connections waiting for a slot: accepted by the kernel but
    // not yet handshaken (no preamble written). Bounded by max_conns,
    // like the old sync-channel backlog.
    let mut parked: VecDeque<TcpStream> = VecDeque::new();
    let mut rotor = 0usize;
    let mut hand_off = |stream: TcpStream, rotor: &mut usize| {
        shared.active.fetch_add(1, Ordering::Relaxed);
        let t = *rotor % inboxes.len();
        *rotor = rotor.wrapping_add(1);
        inboxes[t].lock().unwrap().push_back(stream);
        wakers[t].wake();
    };
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            return; // parked connections are dropped unanswered
        }
        // promote parked connections into freed slots first — FIFO
        while shared.active.load(Ordering::Relaxed) < max_conns {
            match parked.pop_front() {
                Some(s) => hand_off(s, &mut rotor),
                None => break,
            }
        }
        match listener.accept() {
            Ok((stream, _)) => {
                dispatch.event(PlaneEvent::Connection);
                let _ = stream.set_nodelay(true);
                if shared.active.load(Ordering::Relaxed) < max_conns {
                    hand_off(stream, &mut rotor);
                } else if parked.len() < max_conns {
                    parked.push_back(stream);
                } else {
                    // every slot and the backlog full: shed at the door
                    // with an explicit overload handshake
                    dispatch.event(PlaneEvent::ConnectionShed);
                    shed_connection(stream, dispatch.shed_message());
                }
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_TICK);
            }
            Err(_) => {
                // accept failures (EMFILE under fd pressure) can repeat
                // instantly: back off instead of busy-spinning a core
                // exactly when the process is already overloaded
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Best-effort overload handshake for a connection the plane cannot take:
/// preamble + `Overloaded` error frame, then close.
fn shed_connection(mut stream: TcpStream, message: String) {
    let _ = stream.set_write_timeout(Some(WRITE_STALL));
    let mut bytes = proto::encode_preamble().to_vec();
    bytes.extend_from_slice(
        &Frame::Error(ErrorFrame { id: 0, code: ErrorCode::Overloaded, message }).to_bytes(),
    );
    let _ = stream.write_all(&bytes);
}

/// Encode one error frame to wire bytes.
pub(crate) fn error_bytes(id: u64, code: ErrorCode, message: String) -> Vec<u8> {
    Frame::Error(ErrorFrame { id, code, message }).to_bytes()
}

#[inline]
fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

enum ConnState {
    /// Waiting for the 8-byte client preamble.
    Handshake { buf: [u8; proto::PREAMBLE_LEN], filled: usize },
    /// Handshaken; framed request loop.
    Open,
}

/// One multiplexed connection owned by a net thread.
struct Conn {
    stream: TcpStream,
    fd: RawFd,
    token: u64,
    reader: FrameReader,
    state: ConnState,
    /// When the connection reached this net thread (handshake clock).
    opened: Instant,
    /// Negotiated peer protocol version (0 until the preamble lands).
    peer_version: u32,
    /// Handshake span, set when the preamble lands.
    accept_ns: u64,
    /// First-byte instant of the currently partial request frame.
    frame_started: Option<Instant>,
    /// Encoded reply frames not yet (fully) written; `front_written`
    /// bytes of the front entry are already on the wire.
    writeq: VecDeque<Vec<u8>>,
    front_written: usize,
    /// Admitted requests whose completion has not yet arrived.
    pending: usize,
    /// Write interest currently registered with the poller.
    want_write: bool,
    /// Flush the queue, then close (error replies that end the stream).
    close_after_flush: bool,
    /// Last instant the write queue made byte progress.
    last_write_progress: Instant,
}

/// Outcome of driving one connection's readable side.
enum ReadStep {
    Idle,
    Frame(Frame),
    Close,
    Protocol(String),
}

struct IoThread {
    poller: Poller,
    dispatch: Arc<dyn Dispatch>,
    shared: Arc<Shared>,
    cfg: PlaneConfig,
    sink: CompletionSink,
    /// This thread's index into the [`PlaneStats`] per-thread arrays.
    index: usize,
    conns: Vec<Option<Conn>>,
    gens: Vec<u32>,
    free: Vec<usize>,
}

impl IoThread {
    fn run(&mut self, inbox: Arc<Mutex<VecDeque<TcpStream>>>, completions: Receiver<Completion>) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            let woken = match self.poller.wait(&mut events, Some(POLL_TICK)) {
                Ok(w) => w,
                Err(_) => {
                    // a failing wait would otherwise busy-spin; yield
                    std::thread::sleep(POLL_TICK);
                    false
                }
            };
            if woken || !events.is_empty() {
                // per-instance exact books always record; the global
                // registry only mirrors when enabled
                if let Some(w) = self.cfg.stats.wakeups.get(self.index) {
                    w.fetch_add(1, Ordering::Relaxed);
                }
                if obs::enabled() {
                    obs::counter(CounterId::NetEpollWakeups).inc();
                }
            }
            if self.shared.shutdown.load(Ordering::Relaxed) {
                // flush what already completed, notify, tear down
                while let Ok(c) = completions.try_recv() {
                    self.apply_completion(c);
                }
                self.shutdown_all();
                return;
            }
            loop {
                let next = inbox.lock().unwrap().pop_front();
                match next {
                    Some(stream) => self.register(stream),
                    None => break,
                }
            }
            while let Ok(c) = completions.try_recv() {
                self.apply_completion(c);
            }
            for i in 0..events.len() {
                let ev = events[i];
                self.on_event(ev);
            }
            self.scan_deadlines();
            // publish this thread's write-queue depth; the gauge mirrors
            // the cross-thread sum so one stats read sees the whole plane
            let depth: usize =
                self.conns.iter().flatten().map(|c| c.writeq.len()).sum();
            if let Some(d) = self.cfg.stats.writeq_depth.get(self.index) {
                d.store(depth as u64, Ordering::Relaxed);
            }
            if obs::enabled() {
                obs::gauge(GaugeId::NetWriteqDepth)
                    .set(self.cfg.stats.total_writeq_depth() as f64);
            }
        }
    }

    /// Adopt a connection handed over by the acceptor.
    fn register(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            self.shared.active.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.conns.push(None);
                self.gens.push(0);
                self.conns.len() - 1
            }
        };
        let gen = self.gens[slot];
        let token = ((gen as u64) << 32) | slot as u64;
        let fd = raw_fd(&stream);
        if self.poller.add(fd, token, Interest::READ).is_err() {
            self.free.push(slot);
            self.shared.active.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let now = Instant::now();
        self.conns[slot] = Some(Conn {
            stream,
            fd,
            token,
            reader: FrameReader::new(self.cfg.max_frame),
            state: ConnState::Handshake { buf: [0u8; proto::PREAMBLE_LEN], filled: 0 },
            opened: now,
            peer_version: 0,
            accept_ns: 0,
            frame_started: None,
            writeq: VecDeque::new(),
            front_written: 0,
            pending: 0,
            want_write: false,
            close_after_flush: false,
            last_write_progress: now,
        });
    }

    fn close(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            let _ = self.poller.delete(conn.fd);
            self.gens[slot] = self.gens[slot].wrapping_add(1);
            self.free.push(slot);
            self.shared.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    fn on_event(&mut self, ev: Event) {
        let slot = (ev.token & 0xFFFF_FFFF) as usize;
        let gen = (ev.token >> 32) as u32;
        if slot >= self.conns.len() || self.gens[slot] != gen || self.conns[slot].is_none() {
            return; // stale event for a recycled slot
        }
        if ev.hangup && !ev.readable && !ev.writable {
            self.close(slot);
            return;
        }
        if ev.readable || ev.hangup {
            // drive the read side first: it consumes pending bytes and
            // observes EOF/hangup through the normal error path
            if !self.drive_readable(slot) {
                self.close(slot);
                return;
            }
        }
        if ev.writable && self.conns[slot].is_some() && !self.drive_writable(slot) {
            self.close(slot);
        }
    }

    /// Queue reply bytes and flush opportunistically. Returns `false`
    /// when the connection must close now (write error, or the queue
    /// drained with `close_after_flush` set).
    fn enqueue(&mut self, slot: usize, bytes: Vec<u8>) -> bool {
        let Some(conn) = self.conns[slot].as_mut() else { return false };
        conn.writeq.push_back(bytes);
        match flush_conn(&self.poller, conn) {
            Err(_) => false,
            Ok(()) => !(conn.writeq.is_empty() && conn.close_after_flush),
        }
    }

    /// Queue a final reply: flush what we can, then close.
    fn enqueue_closing(&mut self, slot: usize, bytes: Vec<u8>) -> bool {
        if let Some(conn) = self.conns[slot].as_mut() {
            conn.close_after_flush = true;
        }
        self.enqueue(slot, bytes)
    }

    fn drive_readable(&mut self, slot: usize) -> bool {
        // a closing connection only flushes; reading more could enqueue
        // duplicate error frames
        match self.conns[slot].as_ref() {
            None => return false,
            Some(conn) if conn.close_after_flush => return true,
            Some(_) => {}
        }
        // --- handshake phase -------------------------------------------
        enum Hs {
            AlreadyOpen,
            More,
            CloseSilent,
            OpenOk(u32),
            BadVersion(u32),
        }
        let hs = {
            let Some(conn) = self.conns[slot].as_mut() else { return false };
            let Conn { ref mut stream, ref mut state, .. } = *conn;
            match state {
                ConnState::Open => Hs::AlreadyOpen,
                ConnState::Handshake { buf, filled } => match proto::poll_exact(stream, buf, filled)
                {
                    Ok(false) => Hs::More,
                    Err(_) => Hs::CloseSilent,
                    Ok(true) => match proto::decode_preamble(buf) {
                        Ok(v) if (proto::MIN_VERSION..=proto::VERSION).contains(&v) => {
                            Hs::OpenOk(v)
                        }
                        Ok(v) => Hs::BadVersion(v),
                        // wrong magic: not our protocol, close silently
                        Err(_) => Hs::CloseSilent,
                    },
                },
            }
        };
        match hs {
            Hs::AlreadyOpen => {}
            Hs::More => return true,
            Hs::CloseSilent => return false,
            Hs::BadVersion(v) => {
                let mut bytes = proto::encode_preamble().to_vec();
                bytes.extend_from_slice(&error_bytes(
                    0,
                    ErrorCode::UnsupportedVersion,
                    format!(
                        "server speaks v{} (accepts ≥ v{}), client sent v{v}",
                        proto::VERSION,
                        proto::MIN_VERSION
                    ),
                ));
                return self.enqueue_closing(slot, bytes);
            }
            Hs::OpenOk(v) => {
                let accept_ns = {
                    let conn = self.conns[slot].as_mut().expect("conn checked above");
                    conn.state = ConnState::Open;
                    conn.peer_version = v;
                    conn.accept_ns = dur_ns(conn.opened.elapsed());
                    conn.accept_ns
                };
                if obs::enabled() {
                    obs::hist(HistId::NetHandshake).record_ns(accept_ns);
                }
                let hello = self.dispatch.hello_bytes();
                if !self.enqueue(slot, hello) {
                    return false;
                }
                // fall through: request bytes may already be buffered
            }
        }
        // --- framed request loop ---------------------------------------
        loop {
            let step = {
                let Some(conn) = self.conns[slot].as_mut() else { return false };
                let Conn { ref mut stream, ref mut reader, ref mut frame_started, .. } = *conn;
                match reader.poll_frame(stream) {
                    Ok(None) => {
                        // would-block: track partial-frame progress for
                        // the slow-loris deadline
                        if reader.buffered_len() == 0 {
                            *frame_started = None;
                        } else if frame_started.is_none() {
                            *frame_started = Some(Instant::now());
                        }
                        ReadStep::Idle
                    }
                    Ok(Some(frame)) => {
                        *frame_started = None;
                        ReadStep::Frame(frame)
                    }
                    Err(WireError::Closed) => ReadStep::Close,
                    Err(WireError::Io(_)) => ReadStep::Close,
                    Err(e) => ReadStep::Protocol(e.to_string()),
                }
            };
            match step {
                ReadStep::Idle => return true,
                ReadStep::Close => return false,
                ReadStep::Protocol(msg) => {
                    // protocol violation: the stream is no longer framed —
                    // report once and close
                    let bytes = error_bytes(0, ErrorCode::Malformed, msg);
                    return self.enqueue_closing(slot, bytes);
                }
                ReadStep::Frame(frame) => {
                    if !self.handle_frame(slot, frame) {
                        return false;
                    }
                    match self.conns[slot].as_ref() {
                        None => return true, // already torn down
                        // stop reading once the connection is closing
                        Some(conn) if conn.close_after_flush => return true,
                        Some(_) => {}
                    }
                }
            }
        }
    }

    fn handle_frame(&mut self, slot: usize, frame: Frame) -> bool {
        match frame {
            Frame::Request(req) => {
                let (key, accept_ns, decode_ns, over, peer_version) = {
                    let Some(conn) = self.conns[slot].as_ref() else { return false };
                    let key = ConnKey { slot: slot as u32, gen: self.gens[slot] };
                    let over = conn.pending + conn.writeq.len() >= self.cfg.max_inflight.max(1);
                    (key, conn.accept_ns, conn.reader.last_decode_ns(), over, conn.peer_version)
                };
                if req.trace.is_some() && peer_version < proto::VERSION {
                    // a v2-negotiated peer has no trace-context field in
                    // its contract: reject as a protocol violation rather
                    // than guessing at the 9 extra bytes' meaning
                    let bytes = error_bytes(
                        req.id,
                        ErrorCode::Malformed,
                        format!(
                            "trace context on a v{peer_version}-negotiated connection \
                             (requires v{})",
                            proto::VERSION
                        ),
                    );
                    return self.enqueue_closing(slot, bytes);
                }
                if over {
                    // bounded write queue: the pipeline bound is hit, shed
                    // typed instead of buffering replies without limit
                    let conn = self.conns[slot].as_ref().expect("conn checked above");
                    let msg = format!(
                        "pipeline bound reached ({} in flight, {} replies queued, \
                         max_inflight {})",
                        conn.pending,
                        conn.writeq.len(),
                        self.cfg.max_inflight.max(1)
                    );
                    self.dispatch.event(PlaneEvent::WriteqShed);
                    return self.enqueue(slot, error_bytes(req.id, ErrorCode::Overloaded, msg));
                }
                let rctx = RequestCtx { key, accept_ns, decode_ns };
                match self.dispatch.on_request(rctx, req, &self.sink) {
                    RequestAction::Reply(bytes) => self.enqueue(slot, bytes),
                    RequestAction::Async => {
                        if let Some(conn) = self.conns[slot].as_mut() {
                            conn.pending += 1;
                        }
                        true
                    }
                }
            }
            Frame::StatsRequest(s) => {
                self.dispatch.event(PlaneEvent::StatsServed);
                let json = self.dispatch.snapshot_json();
                let bytes = Frame::StatsResponse(StatsResponseFrame { id: s.id, json }).to_bytes();
                self.enqueue(slot, bytes)
            }
            Frame::FleetStatsRequest(s) => {
                let key = ConnKey { slot: slot as u32, gen: self.gens[slot] };
                match self.dispatch.on_fleet_stats(key, s.id, &self.sink) {
                    Some(RequestAction::Reply(bytes)) => {
                        self.dispatch.event(PlaneEvent::FleetStatsServed);
                        self.enqueue(slot, bytes)
                    }
                    Some(RequestAction::Async) => {
                        self.dispatch.event(PlaneEvent::FleetStatsServed);
                        if let Some(conn) = self.conns[slot].as_mut() {
                            conn.pending += 1;
                        }
                        true
                    }
                    None => {
                        // backends do not aggregate: only routers answer
                        let bytes = error_bytes(
                            s.id,
                            ErrorCode::Malformed,
                            "fleet stats are served by fabric routers only".to_string(),
                        );
                        self.enqueue_closing(slot, bytes)
                    }
                }
            }
            _ => {
                // clients may only send requests
                let bytes = error_bytes(
                    0,
                    ErrorCode::Malformed,
                    "unexpected frame type from client".to_string(),
                );
                self.enqueue_closing(slot, bytes)
            }
        }
    }

    fn drive_writable(&mut self, slot: usize) -> bool {
        let Some(conn) = self.conns[slot].as_mut() else { return true };
        match flush_conn(&self.poller, conn) {
            Err(_) => false,
            Ok(()) => !(conn.writeq.is_empty() && conn.close_after_flush),
        }
    }

    fn apply_completion(&mut self, c: Completion) {
        let slot = c.key.slot as usize;
        if slot >= self.conns.len()
            || self.gens[slot] != c.key.gen
            || self.conns[slot].is_none()
        {
            return; // connection died first; the reply has nowhere to go
        }
        if let Some(conn) = self.conns[slot].as_mut() {
            conn.pending = conn.pending.saturating_sub(1);
        }
        let t_write = Instant::now();
        let alive = self.enqueue(slot, c.bytes);
        if let Some(d) = c.trace {
            if obs::enabled() {
                let mut trace = Trace::begin(d.id);
                trace.trace_id = d.trace_id;
                trace.set(Stage::Accept, d.accept_ns);
                trace.set(Stage::Decode, d.decode_ns);
                trace.set(Stage::QueueWait, d.queue_ns);
                trace.set(Stage::Assembly, d.assembly_ns);
                trace.set(Stage::Compute, d.compute_ns);
                trace.set(Stage::Frame, d.frame_ns);
                trace.set(Stage::Write, dur_ns(t_write.elapsed()).max(1));
                // server-side request time: everything except the peer's
                // handshake pacing
                obs::hist(HistId::NetRequest)
                    .record_ns(trace.total_ns().saturating_sub(d.accept_ns));
                self.dispatch.record_trace(&trace);
            }
        }
        if !alive {
            self.close(slot);
        }
    }

    /// Periodic deadline sweep: handshake window, slow-loris frame
    /// progress, write stalls.
    fn scan_deadlines(&mut self) {
        enum Act {
            Close,
            Loris(usize),
        }
        let now = Instant::now();
        for slot in 0..self.conns.len() {
            let act = match self.conns[slot].as_ref() {
                None => continue,
                Some(conn) => match conn.state {
                    ConnState::Handshake { .. } => {
                        if now.duration_since(conn.opened) > HANDSHAKE_TIMEOUT {
                            Some(Act::Close)
                        } else {
                            None
                        }
                    }
                    ConnState::Open => {
                        let stalled_frame = conn
                            .frame_started
                            .map(|t| now.duration_since(t) > self.cfg.frame_deadline)
                            .unwrap_or(false);
                        let stalled_write = !conn.writeq.is_empty()
                            && now.duration_since(conn.last_write_progress) > WRITE_STALL;
                        if stalled_frame {
                            Some(Act::Loris(conn.reader.buffered_len()))
                        } else if stalled_write {
                            Some(Act::Close)
                        } else {
                            None
                        }
                    }
                },
            };
            match act {
                None => {}
                Some(Act::Close) => self.close(slot),
                Some(Act::Loris(buffered)) => {
                    self.dispatch.event(PlaneEvent::FrameTimeout);
                    let msg = format!(
                        "request frame made no progress within {:?} \
                         ({buffered} bytes buffered); closing",
                        self.cfg.frame_deadline
                    );
                    // best-effort typed notice, then drop the connection
                    let _ = self.enqueue(slot, error_bytes(0, ErrorCode::Timeout, msg));
                    self.close(slot);
                }
            }
        }
    }

    /// Shutdown: best-effort `ShuttingDown` notice to every open
    /// connection, then tear everything down.
    fn shutdown_all(&mut self) {
        for slot in 0..self.conns.len() {
            let open = matches!(
                self.conns[slot].as_ref().map(|c| &c.state),
                Some(ConnState::Open)
            );
            if open {
                let msg = self.dispatch.shutdown_message();
                let _ = self.enqueue(slot, error_bytes(0, ErrorCode::ShuttingDown, msg));
            }
            self.close(slot);
        }
    }
}

/// Write the queue until it drains or the socket would block, and keep
/// the poller's write interest in sync with queue emptiness.
fn flush_conn(poller: &Poller, conn: &mut Conn) -> io::Result<()> {
    loop {
        let Some(front) = conn.writeq.front() else { break };
        match conn.stream.write(&front[conn.front_written..]) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "peer gone")),
            Ok(n) => {
                conn.front_written += n;
                conn.last_write_progress = Instant::now();
                if conn.front_written == front.len() {
                    conn.writeq.pop_front();
                    conn.front_written = 0;
                }
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let want = !conn.writeq.is_empty();
    if want != conn.want_write {
        let interest = if want { Interest::READ_WRITE } else { Interest::READ };
        poller.modify(conn.fd, conn.token, interest)?;
        conn.want_write = want;
    }
    Ok(())
}
