//! Fabric router: a front LCQ-RPC process that owns the shard map and
//! relays client requests to healthy backend replicas.
//!
//! The router is `NetServer`-shaped on its client side — it runs the same
//! event-driven connection plane ([`crate::net::plane`]): epoll readiness
//! loops on a fixed pool of net threads, the same preamble handshake,
//! the same hello frame (the **merged** backend catalog from
//! [`Fabric::merged_catalog`], computed per connection so probe refreshes
//! are visible to new clients), the same typed error frames, the same
//! per-frame slow-loris deadline and per-connection pipeline bound — so a
//! [`crate::net::NetClient`] (including its pipelined batch mode) works
//! against a router unchanged. Decoded requests hop from the net threads
//! to a small **bounded forward-worker pool**; when its queue is full the
//! request is shed typed `Overloaded` instead of stalling the event loop.
//! Each worker forwards over a pooled backend connection with this
//! discipline (full state machine in `docs/FABRIC.md`):
//!
//! * a **per-request deadline** starts when the request frame decodes;
//!   retries and their backoff sleeps are clamped to the remaining
//!   deadline, so the router never outlasts the client's patience;
//! * forward failures are classified: connection drop / IO error marks
//!   the backend `Down` and retries elsewhere; a backend `Overloaded` or
//!   `ShuttingDown` frame marks it `Suspect`/`Down` and retries; model
//!   errors (`UnknownModel`, `WrongDims`, `Internal`) are **relayed** to
//!   the client as-is (another replica would answer the same);
//! * retries draw decorrelated-jitter delays from
//!   [`crate::util::backoff`], seeded per request for reproducibility,
//!   within a bounded retry budget;
//! * when every replica is down or the budget/deadline is exhausted, the
//!   client gets the existing typed `Overloaded`/`Timeout` error frame —
//!   graceful degradation, never a hang or a panic.
//!
//! Fault injection ([`crate::util::fault`]) is consulted at the forward
//! point (connection drops, forced `Overloaded`, response delays, frame
//! corruption), so the failover paths above are exercised determin-
//! istically by `rust/tests/fabric.rs` and `rust/tests/c10k.rs` — with
//! injection disabled the cost is one relaxed atomic load per request.
//!
//! **Cross-tier tracing (v3).** When observability is on, the router
//! stamps every forwarded request with a trace context: the client's
//! trace id if it sent one, else a freshly minted id, with
//! `parent_span = 1` (router hop). The backend records that id into its
//! own trace ring, and the router records a 4-stage span of its own
//! (`pick → forward → backend_wait → relay`, see
//! [`crate::obs::RouterStage`]) under the same id — so one id stitches
//! the client-observed latency into router and backend stage timings. A
//! v2 backend is never sent the trace tail: the forward path lazily
//! re-encodes the request without it for connections negotiated at v2.
//!
//! **Fleet stats (v3).** A `FleetStatsRequest` frame makes the router
//! fan `StatsRequest` out to every known backend over its pooled
//! connections and answer with per-backend sections plus a merged fleet
//! view: counters summed key-wise, latency histograms merged bucket-wise
//! ([`crate::obs::HistogramSnapshot::merge`]), and a health census.

use crate::net::fabric::{BackendConn, Fabric, FabricConfig, HealthState};
use crate::net::plane::{
    self, Completion, CompletionSink, ConnKey, Dispatch, Plane, PlaneConfig, PlaneEvent,
    PlaneStats, RequestAction, RequestCtx,
};
use crate::net::proto::{
    self, ErrorCode, ErrorFrame, FleetStatsResponseFrame, Frame, HelloFrame, RequestFrame,
    StatsRequestFrame, TraceContext, WireError,
};
use crate::net::server::NetConfig;
use crate::obs::{
    self, CounterId, HistId, HistogramSnapshot, RouterStage, Trace, TraceRing, STAGES,
};
use crate::util::backoff::Backoff;
use crate::util::fault::{self, FaultKind};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shutdown-poll tick for the prober loop.
const SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Forward-worker threads relaying requests to backends. Workers block on
/// backend sockets, so they are real threads, distinct from the net
/// threads (which must never block).
const FORWARD_WORKERS: usize = 8;

/// Bound on the forward queue (requests decoded but not yet picked up by
/// a worker). Beyond it, requests are shed typed `Overloaded` — explicit
/// backpressure instead of an unbounded hop.
const FORWARD_QUEUE: usize = 64;

/// Router configuration: the client-facing connection plane plus the
/// fabric behind it.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    /// Client-side knobs (bind address, connection limit, net threads,
    /// pipeline bound, frame cap, per-frame deadline). `inflight_budget`
    /// is unused by the router — backpressure is the backends'
    /// `Overloaded` signal plus the bounded forward queue.
    pub net: NetConfig,
    /// Shard map + routing/health knobs.
    pub fabric: FabricConfig,
}

/// Monotonic router counters (all-time, point-in-time read).
#[derive(Clone, Debug, Default)]
pub struct RouterStatsSnapshot {
    /// Client connections accepted.
    pub connections: u64,
    /// Client connections shed at the door (slots + backlog full).
    pub connections_shed: u64,
    /// Requests answered with a backend response.
    pub requests_ok: u64,
    /// Requests answered with a typed error relayed from a backend.
    pub requests_failed: u64,
    /// Requests shed by the router itself (all replicas down, retry
    /// budget or deadline exhausted, forward queue or pipeline bound
    /// full).
    pub requests_shed: u64,
    /// Forward re-attempts (any backend).
    pub retries: u64,
    /// Forward re-attempts that switched backend.
    pub failovers: u64,
    /// Backend health transitions (sum over backends).
    pub health_transitions: u64,
    /// Hello probes run (sum over backends, success + failure).
    pub probes: u64,
    /// Stats frames served.
    pub stats_requests: u64,
    /// Fleet-stats frames served (backend fan-out + merge).
    pub fleet_stats_requests: u64,
    /// Client connections shed by the per-frame progress deadline.
    pub frame_timeouts: u64,
    /// Requests shed by the per-connection pipeline bound (a subset of
    /// `requests_shed`).
    pub writeq_sheds: u64,
}

/// Per-router exact counters, mirroring into the global `fabric_*`
/// counters (connection counts stay router-local so they never blend
/// with backend servers sharing the process).
#[derive(Default)]
struct RouterStats {
    connections: AtomicU64,
    connections_shed: AtomicU64,
    requests_ok: AtomicU64,
    requests_failed: AtomicU64,
    requests_shed: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    stats_requests: AtomicU64,
    fleet_stats_requests: AtomicU64,
    frame_timeouts: AtomicU64,
    writeq_sheds: AtomicU64,
}

impl RouterStats {
    fn bump(own: &AtomicU64, id: Option<CounterId>) {
        own.fetch_add(1, Ordering::Relaxed);
        if let Some(id) = id {
            if obs::enabled() {
                obs::counter(id).inc();
            }
        }
    }
    fn inc_connections(&self) {
        RouterStats::bump(&self.connections, None);
    }
    fn inc_connections_shed(&self) {
        RouterStats::bump(&self.connections_shed, None);
    }
    fn inc_ok(&self) {
        RouterStats::bump(&self.requests_ok, Some(CounterId::FabricRequestsOk));
    }
    fn inc_failed(&self) {
        RouterStats::bump(&self.requests_failed, Some(CounterId::FabricRequestsFailed));
    }
    fn inc_shed(&self) {
        RouterStats::bump(&self.requests_shed, Some(CounterId::FabricRequestsShed));
    }
    fn inc_retry(&self) {
        RouterStats::bump(&self.retries, Some(CounterId::FabricRetries));
    }
    fn inc_failover(&self) {
        RouterStats::bump(&self.failovers, Some(CounterId::FabricFailovers));
    }
    fn inc_stats(&self) {
        RouterStats::bump(&self.stats_requests, None);
    }
    fn inc_fleet_stats(&self) {
        RouterStats::bump(&self.fleet_stats_requests, Some(CounterId::NetFleetStatsRequests));
    }
    fn inc_frame_timeout(&self) {
        RouterStats::bump(&self.frame_timeouts, Some(CounterId::NetFrameTimeouts));
    }
    fn inc_writeq_shed(&self) {
        RouterStats::bump(&self.writeq_sheds, Some(CounterId::NetWriteqSheds));
    }
}

struct RouterCtx {
    fabric: Fabric,
    shutdown: AtomicBool,
    stats: RouterStats,
    /// Router-side spans (pick/forward/backend_wait/relay), keyed by the
    /// same trace id the backend records — the stitch point.
    traces: TraceRing,
    /// Mint for trace ids when the client did not send one.
    next_trace: AtomicU64,
    /// Per-net-thread plane books (wakeups, writeq depth).
    plane_stats: Arc<PlaneStats>,
}

/// One decoded client request on its hop from a net thread to a forward
/// worker.
struct ForwardJob {
    key: ConnKey,
    req: RequestFrame,
    /// Replica indices serving the model (validated non-empty on the net
    /// thread).
    candidates: Vec<usize>,
    /// When the request frame decoded; the deadline anchors here, so
    /// queue wait counts against it.
    t_start: Instant,
    sink: CompletionSink,
}

/// One fleet-stats fan-out on its hop to a forward worker (workers block
/// on backend sockets; net threads must not).
struct FleetJob {
    key: ConnKey,
    id: u64,
    sink: CompletionSink,
}

/// Work items crossing the net-thread → forward-worker queue.
enum Job {
    /// Relay one client request to a backend.
    Forward(ForwardJob),
    /// Fan `StatsRequest` to every backend and merge.
    Fleet(FleetJob),
}

/// The fabric front end: event plane + forward workers + backend fabric +
/// the hello-probe loop, one self-contained unit (see module docs).
pub struct RouterServer {
    ctx: Arc<RouterCtx>,
    local_addr: SocketAddr,
    plane: Option<Plane>,
    forward_tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
}

impl RouterServer {
    /// Bind the client-facing listener, probe every backend once (so the
    /// first hello already carries the merged catalog), and start
    /// accepting. Backends that are down at startup are marked `Down`
    /// and recovered by the probe loop — starting order is free.
    pub fn start(cfg: RouterConfig) -> Result<RouterServer> {
        let listener = TcpListener::bind(&cfg.net.bind_addr)
            .with_context(|| format!("binding {}", cfg.net.bind_addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let max_frame = cfg.net.max_frame_bytes.max(1024);
        let fabric = Fabric::new(cfg.fabric, max_frame);
        fabric.probe_all();
        let plane_stats = Arc::new(PlaneStats::new(cfg.net.net_threads.max(1)));
        let ctx = Arc::new(RouterCtx {
            fabric,
            shutdown: AtomicBool::new(false),
            stats: RouterStats::default(),
            traces: TraceRing::new(cfg.net.trace_slots.max(2)),
            next_trace: AtomicU64::new(1),
            plane_stats: Arc::clone(&plane_stats),
        });
        let (forward_tx, forward_rx) = mpsc::sync_channel::<Job>(FORWARD_QUEUE);
        let forward_rx = Arc::new(Mutex::new(forward_rx));
        let mut workers = Vec::with_capacity(FORWARD_WORKERS);
        for i in 0..FORWARD_WORKERS {
            let ctx = Arc::clone(&ctx);
            let rx = Arc::clone(&forward_rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lcq-router-fwd{i}"))
                    .spawn(move || forward_worker(ctx, rx))
                    .context("spawning forward worker")?,
            );
        }
        let plane_cfg = PlaneConfig {
            name: "lcq-router",
            max_connections: cfg.net.max_connections.max(1),
            net_threads: cfg.net.net_threads.max(1),
            max_inflight: cfg.net.max_inflight.max(1),
            max_frame,
            frame_deadline: cfg.net.frame_deadline.max(SHUTDOWN_POLL),
            stats: plane_stats,
        };
        let dispatch: Arc<dyn Dispatch> = Arc::new(RouterDispatch {
            ctx: Arc::clone(&ctx),
            forward_tx: forward_tx.clone(),
        });
        let plane = Plane::start(listener, dispatch, plane_cfg)
            .context("starting router event plane")?;
        let prober = if ctx.fabric.cfg().probe_every.is_zero() {
            None
        } else {
            let ctx = Arc::clone(&ctx);
            Some(
                std::thread::Builder::new()
                    .name("lcq-router-probe".to_string())
                    .spawn(move || prober_loop(ctx))
                    .context("spawning router prober")?,
            )
        };
        Ok(RouterServer {
            ctx,
            local_addr,
            plane: Some(plane),
            forward_tx: Some(forward_tx),
            workers,
            prober,
        })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Router counters (exact, per instance).
    pub fn stats(&self) -> RouterStatsSnapshot {
        let s = &self.ctx.stats;
        RouterStatsSnapshot {
            connections: s.connections.load(Ordering::Relaxed),
            connections_shed: s.connections_shed.load(Ordering::Relaxed),
            requests_ok: s.requests_ok.load(Ordering::Relaxed),
            requests_failed: s.requests_failed.load(Ordering::Relaxed),
            requests_shed: s.requests_shed.load(Ordering::Relaxed),
            retries: s.retries.load(Ordering::Relaxed),
            failovers: s.failovers.load(Ordering::Relaxed),
            health_transitions: self.ctx.fabric.health_transitions_total(),
            probes: self.ctx.fabric.probes_total(),
            stats_requests: s.stats_requests.load(Ordering::Relaxed),
            fleet_stats_requests: s.fleet_stats_requests.load(Ordering::Relaxed),
            frame_timeouts: s.frame_timeouts.load(Ordering::Relaxed),
            writeq_sheds: s.writeq_sheds.load(Ordering::Relaxed),
        }
    }

    /// The router's trace ring (router-side spans keyed by trace id).
    pub fn traces(&self) -> Vec<Trace> {
        self.ctx.traces.snapshot()
    }

    /// The fabric behind this router (tests inspect backend health).
    pub fn fabric(&self) -> &Fabric {
        &self.ctx.fabric
    }

    /// The full router snapshot (counters + per-backend states + process
    /// registry) as a JSON document — also served over the wire for
    /// `Stats` frames.
    pub fn snapshot_json(&self) -> String {
        snapshot_json(&self.ctx)
    }

    /// Stop the event plane, drain the forward workers, join the prober.
    /// Idempotent; also run on drop. Backends are *not* stopped — the
    /// router does not own them.
    pub fn stop(&mut self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        if let Some(mut p) = self.plane.take() {
            p.stop();
        }
        // the plane's threads held the dispatcher (and its sender clone);
        // dropping ours disconnects the queue and the workers drain out —
        // their late completions land in dead sinks harmlessly
        drop(self.forward_tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Render the router snapshot (schema in `docs/FABRIC.md`).
fn snapshot_json(ctx: &RouterCtx) -> String {
    let ring = ctx.traces.snapshot();
    Json::obj(vec![
        ("router", router_counters_json(ctx)),
        ("backends", ctx.fabric.backends_json()),
        ("process", obs::global().snapshot_json()),
        ("plane", ctx.plane_stats.to_json()),
        ("traces", obs::router_traces_json(&ctx.traces.slowest(8))),
        ("traces_dropped", Json::from(ctx.traces.dropped() as usize)),
        ("trace_ids", obs::trace_ids_json(&ring)),
    ])
    .to_string()
}

/// The `"router"` counter object shared by `Stats` and `FleetStats`
/// replies.
fn router_counters_json(ctx: &RouterCtx) -> Json {
    let s = &ctx.stats;
    Json::obj(vec![
        ("connections", Json::from(s.connections.load(Ordering::Relaxed) as usize)),
        (
            "connections_shed",
            Json::from(s.connections_shed.load(Ordering::Relaxed) as usize),
        ),
        ("requests_ok", Json::from(s.requests_ok.load(Ordering::Relaxed) as usize)),
        (
            "requests_failed",
            Json::from(s.requests_failed.load(Ordering::Relaxed) as usize),
        ),
        ("requests_shed", Json::from(s.requests_shed.load(Ordering::Relaxed) as usize)),
        ("retries", Json::from(s.retries.load(Ordering::Relaxed) as usize)),
        ("failovers", Json::from(s.failovers.load(Ordering::Relaxed) as usize)),
        (
            "health_transitions",
            Json::from(ctx.fabric.health_transitions_total() as usize),
        ),
        ("probes", Json::from(ctx.fabric.probes_total() as usize)),
        ("stats_requests", Json::from(s.stats_requests.load(Ordering::Relaxed) as usize)),
        (
            "fleet_stats_requests",
            Json::from(s.fleet_stats_requests.load(Ordering::Relaxed) as usize),
        ),
        ("frame_timeouts", Json::from(s.frame_timeouts.load(Ordering::Relaxed) as usize)),
        ("writeq_sheds", Json::from(s.writeq_sheds.load(Ordering::Relaxed) as usize)),
    ])
}

fn prober_loop(ctx: Arc<RouterCtx>) {
    let period = ctx.fabric.cfg().probe_every;
    let mut last = Instant::now();
    while !ctx.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(SHUTDOWN_POLL.min(period));
        if last.elapsed() >= period {
            ctx.fabric.probe_all();
            last = Instant::now();
        }
    }
}

#[inline]
fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// The router's [`Dispatch`] implementation: catalog validation on the
/// net thread, then the hop to the forward workers.
struct RouterDispatch {
    ctx: Arc<RouterCtx>,
    forward_tx: SyncSender<ForwardJob>,
}

impl Dispatch for RouterDispatch {
    fn hello_bytes(&self) -> Vec<u8> {
        // the merged backend catalog, computed per connection so probe
        // refreshes are visible to new clients
        let mut out = proto::encode_preamble().to_vec();
        out.extend_from_slice(
            &Frame::Hello(HelloFrame { models: self.ctx.fabric.merged_catalog() }).to_bytes(),
        );
        out
    }

    fn snapshot_json(&self) -> String {
        snapshot_json(&self.ctx)
    }

    fn shed_message(&self) -> String {
        "router connection limit reached".to_string()
    }

    fn shutdown_message(&self) -> String {
        "router shutting down".to_string()
    }

    fn event(&self, ev: PlaneEvent) {
        match ev {
            PlaneEvent::Connection => self.ctx.stats.inc_connections(),
            PlaneEvent::ConnectionShed => self.ctx.stats.inc_connections_shed(),
            PlaneEvent::FrameTimeout => self.ctx.stats.inc_frame_timeout(),
            PlaneEvent::StatsServed => self.ctx.stats.inc_stats(),
            PlaneEvent::WriteqShed => {
                self.ctx.stats.inc_shed();
                self.ctx.stats.inc_writeq_shed();
            }
            PlaneEvent::FleetStatsServed => self.ctx.stats.inc_fleet_stats(),
        }
    }

    fn on_request(
        &self,
        rctx: RequestCtx,
        req: RequestFrame,
        sink: &CompletionSink,
    ) -> RequestAction {
        let ctx = &self.ctx;
        let candidates = ctx.fabric.candidates(&req.model);
        if candidates.is_empty() {
            ctx.stats.inc_failed();
            return RequestAction::Reply(plane::error_bytes(
                req.id,
                ErrorCode::UnknownModel,
                format!("no shard serves model '{}'", req.model),
            ));
        }
        // trace context: adopt the client's id (it wants to stitch its
        // own observations in) or mint one; either way the forwarded
        // request is stamped `parent_span = 1` so the backend knows the
        // hop came through a router
        let mut req = req;
        if obs::enabled() {
            let trace_id = match req.trace {
                Some(t) if t.trace_id != 0 => t.trace_id,
                _ => ctx.next_trace.fetch_add(1, Ordering::Relaxed),
            };
            req.trace = Some(TraceContext { trace_id, parent_span: 1 });
        } else if let Some(t) = req.trace.as_mut() {
            t.parent_span = 1;
        }
        let req_id = req.id;
        let job = Job::Forward(ForwardJob {
            key: rctx.key,
            req,
            candidates,
            t_start: Instant::now(),
            sink: sink.clone(),
        });
        match self.forward_tx.try_send(job) {
            Ok(()) => RequestAction::Async,
            Err(TrySendError::Full(_)) => {
                // the worker pool is saturated: shed typed instead of
                // stalling the net thread
                ctx.stats.inc_shed();
                RequestAction::Reply(plane::error_bytes(
                    req_id,
                    ErrorCode::Overloaded,
                    format!("router forward queue full ({FORWARD_QUEUE} requests deep)"),
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                ctx.stats.inc_shed();
                RequestAction::Reply(plane::error_bytes(
                    req_id,
                    ErrorCode::ShuttingDown,
                    "router shutting down".to_string(),
                ))
            }
        }
    }

    fn on_fleet_stats(
        &self,
        key: ConnKey,
        id: u64,
        sink: &CompletionSink,
    ) -> Option<RequestAction> {
        // the fan-out blocks on backend sockets, so it rides the forward
        // workers like any other backend-touching work
        let job = Job::Fleet(FleetJob { key, id, sink: sink.clone() });
        Some(match self.forward_tx.try_send(job) {
            Ok(()) => RequestAction::Async,
            Err(TrySendError::Full(_)) => {
                self.ctx.stats.inc_shed();
                RequestAction::Reply(plane::error_bytes(
                    id,
                    ErrorCode::Overloaded,
                    format!("router forward queue full ({FORWARD_QUEUE} requests deep)"),
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                self.ctx.stats.inc_shed();
                RequestAction::Reply(plane::error_bytes(
                    id,
                    ErrorCode::ShuttingDown,
                    "router shutting down".to_string(),
                ))
            }
        })
    }
}

/// Forward-worker loop: route each job, post the encoded reply back to
/// its net thread.
fn forward_worker(ctx: Arc<RouterCtx>, rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = { rx.lock().unwrap().recv() };
        match job {
            Ok(Job::Forward(job)) => {
                let bytes = route_job(&ctx, job.req, &job.candidates, job.t_start);
                job.sink.send(Completion { key: job.key, bytes, trace: None });
            }
            Ok(Job::Fleet(job)) => {
                let t0 = Instant::now();
                let json = fleet_stats_json(&ctx, job.id);
                if obs::enabled() {
                    obs::hist(HistId::FabricFleetFanout).record_ns(dur_ns(t0.elapsed()));
                }
                let bytes =
                    Frame::FleetStatsResponse(FleetStatsResponseFrame { id: job.id, json })
                        .to_bytes();
                job.sink.send(Completion { key: job.key, bytes, trace: None });
            }
            Err(_) => return, // queue disconnected: router stopping
        }
    }
}

/// What one forward attempt produced.
enum Forward {
    /// Backend answered; relay these frame bytes to the client verbatim
    /// (response or typed model error — another replica would say the
    /// same, so this ends the request).
    Answer { frame: Frame, ok: bool },
    /// Connection-level failure (dial/IO/protocol/desync). Drop the
    /// conn, mark the backend `Down`, retry elsewhere.
    ConnFailed(String),
    /// Backend shed with `Overloaded`. Conn stays framed; mark the
    /// backend `Suspect`, retry elsewhere.
    Overloaded,
    /// Backend answered `ShuttingDown`. Drop the conn, mark `Down`,
    /// retry elsewhere.
    ShuttingDown,
    /// The per-request deadline expired while waiting on the backend.
    /// Drop the conn (an unread response would desync it), mark
    /// `Suspect`.
    DeadlineMidRead,
}

/// Route one request: pick → forward → classify, within the retry budget
/// and deadline. Returns the encoded reply frame for the client; counters
/// bump here (before the reply travels), as they always have.
fn route_job(
    ctx: &RouterCtx,
    req: RequestFrame,
    candidates: &[usize],
    t_start: Instant,
) -> Vec<u8> {
    let cfg = ctx.fabric.cfg();
    let deadline = t_start + cfg.deadline;
    let req_id = req.id;
    let trace_id = req.trace.map(|t| t.trace_id).unwrap_or(0);
    let model = req.model.clone();
    let shed = |ctx: &RouterCtx, code: ErrorCode, msg: String| -> Vec<u8> {
        ctx.stats.inc_shed();
        plane::error_bytes(req_id, code, msg)
    };
    // the forwarded bytes are encoded once; retries resend them verbatim.
    // The frame is kept around so a v2-negotiated backend can get a lazy
    // re-encode without the trace tail (`compat`, computed at most once).
    let fwd_frame = Frame::Request(req);
    let bytes = fwd_frame.to_bytes();
    let mut compat: Option<Vec<u8>> = None;
    // router-side span accumulator (RouterStage indices 0..ROUTER_STAGES)
    let mut spans = [0u64; STAGES];
    // per-request backoff stream: reproducible given (fabric seed, id)
    let mut backoff = Backoff::new(cfg.backoff, cfg.seed ^ req_id.wrapping_mul(0x9E37_79B9));
    let mut last_failed: Option<usize> = None;
    for attempt in 0..cfg.retry_budget.max(1) {
        if attempt > 0 {
            ctx.stats.inc_retry();
            let delay = backoff.next_delay();
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return shed(
                    ctx,
                    ErrorCode::Timeout,
                    format!("deadline exhausted after {attempt} attempts for '{model}'"),
                );
            }
            if !delay.is_zero() {
                std::thread::sleep(delay.min(remaining));
            }
        }
        let t_pick = Instant::now();
        let picked = ctx.fabric.pick(candidates, last_failed);
        spans[RouterStage::Pick as usize] += dur_ns(t_pick.elapsed());
        let Some(idx) = picked else {
            return shed(
                ctx,
                ErrorCode::Overloaded,
                format!("all replicas for '{model}' are down"),
            );
        };
        if attempt > 0 && Some(idx) != last_failed {
            ctx.stats.inc_failover();
        }
        let t_fwd = Instant::now();
        let mut fwd = FwdBytes { frame: &fwd_frame, v3: &bytes, compat: &mut compat };
        let outcome = forward_once(ctx, idx, &mut fwd, req_id, deadline, &mut spans);
        if obs::enabled() {
            obs::hist(HistId::FabricBackendRtt).record_ns(dur_ns(t_fwd.elapsed()));
        }
        match outcome {
            Forward::Answer { frame, ok } => {
                ctx.fabric.set_state(idx, HealthState::Healthy);
                ctx.fabric.backends()[idx].inc_forward_ok();
                if ok {
                    ctx.stats.inc_ok();
                } else {
                    ctx.stats.inc_failed();
                }
                if obs::enabled() {
                    obs::hist(HistId::FabricRequest).record_ns(dur_ns(t_start.elapsed()));
                }
                let t_relay = Instant::now();
                let out = frame.to_bytes();
                spans[RouterStage::Relay as usize] += dur_ns(t_relay.elapsed());
                if trace_id != 0 && obs::enabled() {
                    record_router_trace(ctx, Trace::from_parts(req_id, trace_id, spans));
                }
                return out;
            }
            Forward::ConnFailed(_) => {
                ctx.fabric.backends()[idx].inc_forward_failed();
                ctx.fabric.backends()[idx].drain_pool();
                ctx.fabric.set_state(idx, HealthState::Down);
                last_failed = Some(idx);
            }
            Forward::Overloaded => {
                ctx.fabric.backends()[idx].inc_forward_failed();
                ctx.fabric.set_state(idx, HealthState::Suspect);
                last_failed = Some(idx);
            }
            Forward::ShuttingDown => {
                ctx.fabric.backends()[idx].inc_forward_failed();
                ctx.fabric.backends()[idx].drain_pool();
                ctx.fabric.set_state(idx, HealthState::Down);
                last_failed = Some(idx);
            }
            Forward::DeadlineMidRead => {
                ctx.fabric.backends()[idx].inc_forward_failed();
                ctx.fabric.set_state(idx, HealthState::Suspect);
                return shed(
                    ctx,
                    ErrorCode::Timeout,
                    format!("deadline exhausted waiting on a replica for '{model}'"),
                );
            }
        }
        if Instant::now() >= deadline {
            return shed(
                ctx,
                ErrorCode::Timeout,
                format!("deadline exhausted after {} attempts for '{model}'", attempt + 1),
            );
        }
    }
    shed(
        ctx,
        ErrorCode::Overloaded,
        format!("retry budget ({}) exhausted for '{model}'", cfg.retry_budget.max(1)),
    )
}

/// Record a router-side span into the router's trace ring (exact
/// per-instance books; global counters mirror the record/drop outcome).
fn record_router_trace(ctx: &RouterCtx, trace: Trace) {
    if ctx.traces.record(&trace) {
        obs::counter(CounterId::TracesRecorded).inc();
    } else {
        obs::counter(CounterId::TracesDropped).inc();
    }
}

/// The request being forwarded, in both encodings: the v3 bytes (with
/// the trace tail when present) and a lazily built v2-compatible
/// re-encode (trace stripped) for backends negotiated at v2.
struct FwdBytes<'a> {
    frame: &'a Frame,
    v3: &'a [u8],
    compat: &'a mut Option<Vec<u8>>,
}

impl FwdBytes<'_> {
    /// The bytes to put on a connection negotiated at `version`.
    fn for_version(&mut self, version: u32) -> &[u8] {
        let traced = matches!(self.frame, Frame::Request(r) if r.trace.is_some());
        if version >= proto::VERSION || !traced {
            return self.v3;
        }
        self.compat.get_or_insert_with(|| {
            let Frame::Request(r) = self.frame else { unreachable!() };
            let mut bare = r.clone();
            bare.trace = None;
            Frame::Request(bare).to_bytes()
        })
    }
}

/// One forward attempt against backend `idx`: checkout (pooled or fresh
/// dial), send the encoded request, await the matching frame. Fault
/// injection is consulted here — the router-side points are response
/// delay, synthetic connection drop, forced `Overloaded`, and one-byte
/// frame corruption (the backend then answers `Malformed`, which the
/// router treats as a poisoned connection). The checkout+send cost lands
/// in the `forward` span; the read loop lands in `backend_wait`.
fn forward_once(
    ctx: &RouterCtx,
    idx: usize,
    fwd: &mut FwdBytes<'_>,
    req_id: u64,
    deadline: Instant,
    spans: &mut [u64; STAGES],
) -> Forward {
    if fault::enabled() {
        if fault::should_inject(FaultKind::Delay) {
            std::thread::sleep(fault::delay_duration());
        }
        if fault::should_inject(FaultKind::ConnDrop) {
            return Forward::ConnFailed("injected connection drop".to_string());
        }
        if fault::should_inject(FaultKind::Overload) {
            return Forward::Overloaded;
        }
    }
    let t_fwd = Instant::now();
    let mut conn: BackendConn = match ctx.fabric.checkout(idx) {
        Ok(c) => c,
        Err(e) => return Forward::ConnFailed(e),
    };
    let bytes = fwd.for_version(conn.version);
    let send_result = if fault::enabled() && fault::should_inject(FaultKind::Corrupt) {
        let mut copy = bytes.to_vec();
        let last = copy.len() - 1;
        copy[last] ^= 0xFF; // checksum byte: backend sees a checksum error
        conn.stream.write_all(&copy)
    } else {
        conn.stream.write_all(bytes)
    };
    if let Err(e) = send_result {
        return Forward::ConnFailed(format!("send: {e}"));
    }
    spans[RouterStage::Forward as usize] += dur_ns(t_fwd.elapsed());
    let t_wait = Instant::now();
    let outcome = 'wait: loop {
        if Instant::now() >= deadline {
            break 'wait Forward::DeadlineMidRead;
        }
        match conn.reader.poll_frame(&mut conn.stream) {
            Ok(None) => continue, // BACKEND_POLL tick
            Ok(Some(Frame::Response(resp))) => {
                if resp.id != req_id {
                    break 'wait Forward::ConnFailed(format!(
                        "response id {} for request {req_id}",
                        resp.id
                    ));
                }
                let frame = Frame::Response(resp);
                ctx.fabric.backends()[idx].checkin(conn);
                break 'wait Forward::Answer { frame, ok: true };
            }
            Ok(Some(Frame::Error(e))) => {
                if e.id != req_id && e.id != 0 {
                    break 'wait Forward::ConnFailed(format!(
                        "error frame for foreign request {}",
                        e.id
                    ));
                }
                break 'wait match e.code {
                    ErrorCode::Overloaded => {
                        // request-level shed keeps the conn framed
                        ctx.fabric.backends()[idx].checkin(conn);
                        Forward::Overloaded
                    }
                    ErrorCode::ShuttingDown => Forward::ShuttingDown,
                    ErrorCode::Malformed | ErrorCode::UnsupportedVersion => {
                        // the *router's* frame upset the backend (e.g.
                        // injected corruption): never relay, the conn is
                        // closed on the far side
                        Forward::ConnFailed(format!("backend rejected frame: {}", e.message))
                    }
                    _ => {
                        // model-level errors are identical on every
                        // replica: relay, request over
                        let frame = Frame::Error(ErrorFrame {
                            id: req_id,
                            code: e.code,
                            message: e.message,
                        });
                        ctx.fabric.backends()[idx].checkin(conn);
                        Forward::Answer { frame, ok: false }
                    }
                };
            }
            Ok(Some(_)) => {
                break 'wait Forward::ConnFailed("unexpected frame from backend".to_string());
            }
            Err(WireError::Closed) => {
                break 'wait Forward::ConnFailed("backend closed the connection".to_string());
            }
            Err(e) => break 'wait Forward::ConnFailed(e.to_string()),
        }
    };
    spans[RouterStage::BackendWait as usize] += dur_ns(t_wait.elapsed());
    outcome
}

/// Fan `StatsRequest` to every backend and merge: the body of a
/// `FleetStatsRequest`. Returns the reply JSON document (schema in
/// `docs/OBSERVABILITY.md` and `docs/FABRIC.md`): a `"fleet"` section
/// (health census, counters summed key-wise over each backend's
/// `"server"` object, latency histograms merged bucket-wise from each
/// backend's canonical `"batch"."latency"` form), the router's own
/// counters, and a per-backend array carrying each backend's full stats
/// document or the error that kept it out of the merge.
fn fleet_stats_json(ctx: &RouterCtx, id: u64) -> String {
    let backends = ctx.fabric.backends();
    let deadline = Instant::now() + ctx.fabric.cfg().deadline;
    let mut per_backend = Vec::with_capacity(backends.len());
    let mut merged_counters: BTreeMap<String, f64> = BTreeMap::new();
    let mut merged_latency = HistogramSnapshot::empty();
    let mut backends_ok = 0usize;
    let (mut healthy, mut suspect, mut down) = (0usize, 0usize, 0usize);
    for (i, b) in backends.iter().enumerate() {
        match b.state() {
            HealthState::Healthy => healthy += 1,
            HealthState::Suspect => suspect += 1,
            HealthState::Down => down += 1,
        }
        let mut entry = vec![
            ("addr", Json::Str(b.addr().to_string())),
            ("state", Json::Str(b.state().name().to_string())),
        ];
        match backend_stats_once(ctx, i, id, deadline) {
            Ok(doc) => {
                backends_ok += 1;
                if let Some(server) = doc.get("server").and_then(|s| s.as_obj()) {
                    for (k, v) in server {
                        if let Some(n) = v.as_f64() {
                            *merged_counters.entry(k.clone()).or_insert(0.0) += n;
                        }
                    }
                }
                if let Some(h) = doc
                    .get("batch")
                    .and_then(|s| s.get("latency"))
                    .and_then(HistogramSnapshot::from_json)
                {
                    merged_latency.merge(&h);
                }
                entry.push(("ok", Json::Bool(true)));
                entry.push(("stats", doc));
            }
            Err(e) => {
                entry.push(("ok", Json::Bool(false)));
                entry.push(("error", Json::Str(e)));
            }
        }
        per_backend.push(Json::obj(entry));
    }
    let fleet = Json::obj(vec![
        ("backends_total", Json::from(backends.len())),
        ("backends_ok", Json::from(backends_ok)),
        (
            "health",
            Json::obj(vec![
                ("healthy", Json::from(healthy)),
                ("suspect", Json::from(suspect)),
                ("down", Json::from(down)),
            ]),
        ),
        (
            "counters",
            Json::Obj(merged_counters.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
        ("latency", merged_latency.to_json()),
    ]);
    Json::obj(vec![
        ("fleet", fleet),
        ("router", router_counters_json(ctx)),
        ("backends", Json::Arr(per_backend)),
    ])
    .to_string()
}

/// One stats round trip against backend `idx` over a pooled connection
/// (or a fresh dial), id-matched under the fleet deadline. Failures drop
/// the connection (an unread response would desync it) but do not touch
/// routing health — a slow stats answer is not a routing signal.
fn backend_stats_once(
    ctx: &RouterCtx,
    idx: usize,
    id: u64,
    deadline: Instant,
) -> std::result::Result<Json, String> {
    let mut conn: BackendConn = ctx.fabric.checkout(idx)?;
    let bytes = Frame::StatsRequest(StatsRequestFrame { id }).to_bytes();
    conn.stream.write_all(&bytes).map_err(|e| format!("send: {e}"))?;
    loop {
        if Instant::now() >= deadline {
            return Err("deadline exhausted waiting for backend stats".to_string());
        }
        match conn.reader.poll_frame(&mut conn.stream) {
            Ok(None) => continue, // BACKEND_POLL tick
            Ok(Some(Frame::StatsResponse(s))) => {
                if s.id != id {
                    return Err(format!("stats response id {} for request {id}", s.id));
                }
                let doc = Json::parse(&s.json).map_err(|e| format!("stats json: {e}"))?;
                ctx.fabric.backends()[idx].checkin(conn);
                return Ok(doc);
            }
            Ok(Some(Frame::Error(e))) => {
                return Err(format!("backend refused stats: [{}] {}", e.code, e.message));
            }
            Ok(Some(_)) => return Err("unexpected frame from backend".to_string()),
            Err(WireError::Closed) => return Err("backend closed the connection".to_string()),
            Err(e) => return Err(e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_router_config_is_sane() {
        let c = RouterConfig::default();
        assert!(c.fabric.retry_budget >= 1);
        assert!(!c.fabric.deadline.is_zero());
        assert!(c.net.max_connections >= 1);
        assert!(c.net.net_threads >= 1);
        assert!(c.net.max_inflight >= 1);
    }
}
