//! Metrics recording: run histories, CSV/JSON emission for the experiment
//! drivers (each paper figure is regenerated from these files).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// A tabular run history: named columns, rows appended over time.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl History {
    pub fn new(columns: &[&str]) -> History {
        History { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn col(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[idx]).collect())
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Write a JSON results blob (deterministic key order).
pub fn save_json(path: &Path, value: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, value.to_string())
}

/// Summary statistics of a slice.
pub fn summary(xs: &[f32]) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    if xs.is_empty() {
        return m;
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let mut sorted: Vec<f32> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    m.insert("mean".into(), mean);
    m.insert("std".into(), var.sqrt());
    m.insert("min".into(), sorted[0] as f64);
    m.insert("max".into(), *sorted.last().unwrap() as f64);
    let mid = sorted.len() / 2;
    let median = if sorted.len() % 2 == 0 {
        (sorted[mid - 1] as f64 + sorted[mid] as f64) / 2.0
    } else {
        sorted[mid] as f64
    };
    m.insert("median".into(), median);
    m
}

/// q-th percentile (q ∈ [0, 100]) by nearest-rank over an **already
/// sorted** ascending slice; returns 0.0 on empty input. Callers needing
/// several percentiles of one sample (e.g. the serving stats snapshot)
/// sort once and call this repeatedly.
pub fn percentile_sorted(sorted: &[f32], q: f64) -> f32 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// q-th percentile by nearest-rank on a sorted copy (one-shot convenience
/// over [`percentile_sorted`]).
pub fn percentile(xs: &[f32], q: f64) -> f32 {
    let mut sorted: Vec<f32> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Kernel density estimate on a fixed grid — used to reproduce the weight
/// distribution plots (paper Figs. 7, 11–13) as numeric series.
pub fn kde(xs: &[f32], grid: &[f32], bandwidth: f32) -> Vec<f32> {
    let h = bandwidth.max(1e-8) as f64;
    let norm = 1.0 / ((xs.len().max(1) as f64) * h * (2.0 * std::f64::consts::PI).sqrt());
    grid.iter()
        .map(|&g| {
            let mut s = 0.0f64;
            for &x in xs {
                let z = ((g - x) as f64) / h;
                s += (-0.5 * z * z).exp();
            }
            (s * norm) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_roundtrip() {
        let mut h = History::new(&["iter", "loss"]);
        h.push(vec![0.0, 1.5]);
        h.push(vec![1.0, 0.7]);
        let csv = h.to_csv();
        assert_eq!(csv, "iter,loss\n0,1.5\n1,0.7\n");
        assert_eq!(h.col("loss").unwrap(), vec![1.5, 0.7]);
        assert!(h.col("nope").is_none());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut h = History::new(&["a"]);
        h.push(vec![1.0, 2.0]);
    }

    #[test]
    fn summary_stats() {
        // even length: median averages the two middle elements
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s["mean"] - 2.5).abs() < 1e-9);
        assert_eq!(s["min"], 1.0);
        assert_eq!(s["max"], 4.0);
        assert_eq!(s["median"], 2.5);
        // odd length: median is the middle element
        let s = summary(&[5.0, 1.0, 3.0]);
        assert_eq!(s["median"], 3.0);
        // two elements
        let s = summary(&[1.0, 2.0]);
        assert_eq!(s["median"], 1.5);
        // singleton
        let s = summary(&[7.0]);
        assert_eq!(s["median"], 7.0);
        assert!(summary(&[]).is_empty());
    }

    #[test]
    fn percentile_ranks() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((50.0..=51.0).contains(&p50), "{p50}");
        let p99 = percentile(&xs, 99.0);
        assert!((99.0..=100.0).contains(&p99), "{p99}");
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn kde_integrates_to_one() {
        let xs = [0.0f32, 1.0, -1.0, 0.5];
        let grid: Vec<f32> = (0..200).map(|i| -5.0 + i as f32 * 0.05).collect();
        let dens = kde(&xs, &grid, 0.3);
        let integral: f32 = dens.iter().sum::<f32>() * 0.05;
        assert!((integral - 1.0).abs() < 0.02, "integral {integral}");
        // peak near the data
        let peak_idx = dens
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!((grid[peak_idx]).abs() < 1.0);
    }

    #[test]
    fn save_files() {
        let dir = std::env::temp_dir().join("lcquant_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut h = History::new(&["x"]);
        h.push(vec![1.0]);
        let p = dir.join("a/b.csv");
        h.save_csv(&p).unwrap();
        assert!(p.exists());
        save_json(&dir.join("r.json"), &Json::obj(vec![("k", Json::from(1.0))])).unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("r.json")).unwrap(), "{\"k\":1}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
