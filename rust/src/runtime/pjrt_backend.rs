//! [`PjrtBackend`]: the production L-step backend. Loss/gradients come from
//! the AOT-compiled JAX graph (L2) executed via PJRT; the coordinator keeps
//! the parameters and optimizer state in rust — as the same flat
//! [`ParamSet`] arena the native backend uses, so the LC algorithm,
//! BinaryConnect, DC and iDC all run unchanged on this backend.
//!
//! Artifact conventions (see `python/compile/aot.py`):
//! * `<model>_grad`: inputs `[w1, b1, …, wL, bL, x, y]` → outputs
//!   `[loss, dw1, db1, …, dwL, dbL]`, fixed batch size in `meta.batch`.
//! * `<model>_eval`: same inputs → `[loss, errors]` (errors = count).
//!
//! Evaluation walks ⌊n/B⌋ full batches (HLO shapes are static); the ≤B−1
//! remainder is skipped, which perturbs metrics by <0.1% at our sizes.

use super::{literal_f32, scalar_f32, to_vec_f32, Engine};
use crate::coordinator::Backend;
use crate::data::batcher::Batcher;
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::nn::params::{GradBuffer, LayerShape, ParamLayout, ParamSet};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

pub struct PjrtBackend {
    engine: Engine,
    grad_name: String,
    eval_name: String,
    params: ParamSet,
    batch: usize,
    n_classes: usize,
    pub train: Dataset,
    pub test: Option<Dataset>,
    batcher: Batcher,
}

impl PjrtBackend {
    /// Build from an engine + artifact pair. Parameters are initialized
    /// Glorot-uniform (same scheme as the native backend).
    pub fn new(
        engine: Engine,
        model: &str,
        train: Dataset,
        test: Option<Dataset>,
        seed: u64,
    ) -> Result<PjrtBackend> {
        let grad_name = format!("{model}_grad");
        let eval_name = format!("{model}_eval");
        let spec = engine
            .manifest
            .artifacts
            .get(&grad_name)
            .ok_or_else(|| anyhow!("manifest lacks '{grad_name}'"))?;
        let n_inputs = spec.inputs.len();
        if n_inputs < 4 || (n_inputs - 2) % 2 != 0 {
            return Err(anyhow!("'{grad_name}' input arity {n_inputs} not 2L+2"));
        }
        let n_layers = (n_inputs - 2) / 2;
        let batch = spec.meta.get("batch").copied().unwrap_or(128.0) as usize;
        let mut shapes = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let ws = &spec.inputs[2 * l];
            let bs = &spec.inputs[2 * l + 1];
            if ws.shape.len() != 2 {
                return Err(anyhow!("weight input {} not rank-2", ws.name));
            }
            let (fan_in, fan_out) = (ws.shape[0], ws.shape[1]);
            if bs.numel() != fan_out {
                return Err(anyhow!(
                    "bias input {} has {} entries, expected {fan_out}",
                    bs.name,
                    bs.numel()
                ));
            }
            shapes.push(LayerShape { rows: fan_in, cols: fan_out });
        }
        let layout = ParamLayout::new(shapes);
        let mut params = ParamSet::zeros(layout);
        let mut rng = Rng::new(seed);
        for l in 0..n_layers {
            let shape = params.layout().shape(l);
            let limit = (6.0 / (shape.rows + shape.cols) as f32).sqrt();
            for v in params.w_layer_mut(l).iter_mut() {
                *v = rng.uniform_in(-limit, limit);
            }
        }
        let n_classes = train.n_classes;
        let batcher = Batcher::new(train.len(), batch.min(train.len()), seed);
        Ok(PjrtBackend {
            engine,
            grad_name,
            eval_name,
            params,
            batch,
            n_classes,
            train,
            test,
            batcher,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        let n_layers = self.params.n_layers();
        let mut lits = Vec::with_capacity(n_layers * 2);
        for l in 0..n_layers {
            let shape = self.params.layout().shape(l);
            lits.push(literal_f32(self.params.w_layer(l), &[shape.rows, shape.cols])?);
            lits.push(literal_f32(self.params.b_layer(l), &[shape.cols])?);
        }
        Ok(lits)
    }

    fn batch_literals(&self, x: &Mat, y: &Mat) -> Result<(xla::Literal, xla::Literal)> {
        Ok((
            literal_f32(&x.data, &[x.rows, x.cols])?,
            literal_f32(&y.data, &[y.rows, y.cols])?,
        ))
    }

    /// Evaluate (loss, error%) over ⌊n/B⌋ full batches of a dataset.
    fn eval_dataset(&mut self, which_test: bool) -> Result<(f32, f32)> {
        let data = if which_test {
            self.test.as_ref().expect("no test set")
        } else {
            &self.train
        };
        let b = self.batch;
        let n_full = data.len() / b;
        assert!(n_full > 0, "dataset smaller than artifact batch size");
        let dim = data.dim();
        let n_classes = self.n_classes;
        // materialize batches first (borrow gymnastics around engine)
        let mut batches = Vec::with_capacity(n_full);
        for bi in 0..n_full {
            let mut x = Mat::zeros(b, dim);
            let mut y = Mat::zeros(b, n_classes);
            for r in 0..b {
                let i = bi * b + r;
                x.row_mut(r).copy_from_slice(data.images.row(i));
                y[(r, data.labels[i] as usize)] = 1.0;
            }
            batches.push((x, y));
        }
        let mut loss_sum = 0.0f64;
        let mut err_sum = 0.0f64;
        for (x, y) in &batches {
            let (xl, yl) = self.batch_literals(x, y)?;
            let mut inputs = self.param_literals()?;
            inputs.push(xl);
            inputs.push(yl);
            let out = self.engine.execute(&self.eval_name, &inputs)?;
            loss_sum += scalar_f32(&out[0])? as f64;
            err_sum += scalar_f32(&out[1])? as f64; // error count in batch
        }
        Ok((
            (loss_sum / n_full as f64) as f32,
            (100.0 * err_sum / (n_full * b) as f64) as f32,
        ))
    }
}

impl Backend for PjrtBackend {
    fn params(&self) -> &ParamSet {
        &self.params
    }
    fn params_mut(&mut self) -> &mut ParamSet {
        &mut self.params
    }
    fn next_loss_grads_into(&mut self, grads: &mut GradBuffer) -> f32 {
        let batch = self.batcher.next_batch(&self.train);
        let (xl, yl) = self
            .batch_literals(&batch.x, &batch.y)
            .expect("batch literals");
        let mut inputs = self.param_literals().expect("param literals");
        inputs.push(xl);
        inputs.push(yl);
        let out = self
            .engine
            .execute(&self.grad_name, &inputs)
            .expect("grad artifact execution");
        let loss = scalar_f32(&out[0]).expect("loss scalar");
        for l in 0..self.params.n_layers() {
            let dw = to_vec_f32(&out[1 + 2 * l]).expect("dw");
            grads.w_layer_mut(l).copy_from_slice(&dw);
            let db = to_vec_f32(&out[2 + 2 * l]).expect("db");
            grads.b_layer_mut(l).copy_from_slice(&db);
        }
        loss
    }
    fn eval_train(&mut self) -> (f32, f32) {
        self.eval_dataset(false).expect("eval train")
    }
    fn eval_test(&mut self) -> Option<(f32, f32)> {
        if self.test.is_some() {
            Some(self.eval_dataset(true).expect("eval test"))
        } else {
            None
        }
    }
}
