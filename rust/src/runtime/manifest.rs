//! Artifact manifest: shapes, dtypes and argument order for each AOT
//! artifact, written by `python/compile/aot.py` alongside the HLO text.

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// HLO text file, relative to the artifact dir.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (e.g. batch size, codebook size).
    pub meta: BTreeMap<String, f64>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("tensor missing name"))?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(|v| v.as_str())
        .unwrap_or("f32")
        .to_string();
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let path = spec
                .get("path")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing path"))?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                spec.get(key)
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_tensor)
                    .collect()
            };
            let mut meta = BTreeMap::new();
            if let Some(m) = spec.get("meta").and_then(|v| v.as_obj()) {
                for (k, v) in m {
                    if let Some(f) = v.as_f64() {
                        meta.insert(k.clone(), f);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec { path, inputs: parse_list("inputs")?, outputs: parse_list("outputs")?, meta },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading manifest {path:?}: {e}"))?;
        Manifest::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "grad": {
          "path": "grad.hlo.txt",
          "inputs": [
            {"name": "w1", "shape": [4, 3], "dtype": "f32"},
            {"name": "x", "shape": [8, 4], "dtype": "f32"}
          ],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
          "meta": {"batch": 8}
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let g = &m.artifacts["grad"];
        assert_eq!(g.path, "grad.hlo.txt");
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].shape, vec![4, 3]);
        assert_eq!(g.inputs[0].numel(), 12);
        assert_eq!(g.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(g.meta["batch"], 8.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"a": {}}}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
