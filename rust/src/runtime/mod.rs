//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`, produced by `python/compile/aot.py`) and executes them
//! on the CPU PJRT client. Python never runs here — HLO text is the
//! interchange format (the image's xla_extension 0.5.1 rejects jax≥0.5's
//! serialized protos; the text parser reassigns instruction ids).

pub mod manifest;
pub mod pjrt_backend;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt_backend::PjrtBackend;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact cache over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, dir: dir.to_path_buf(), manifest, executables: HashMap::new() })
    }

    /// Default artifact directory: `$LCQUANT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("LCQUANT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if an artifact directory with a manifest exists (used by tests
    /// to skip PJRT coverage when artifacts haven't been built).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    /// Compile (and cache) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.dir.join(&spec.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
            crate::info!("compiled artifact '{name}' from {path:?}");
        }
        Ok(&self.executables[name])
    }

    /// Execute an artifact on literal inputs; returns the flattened tuple
    /// of output literals (aot.py lowers everything with return_tuple=True).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.compile(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal shape {shape:?} != data len {}", data.len()));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal shape {shape:?} != data len {}", data.len()));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Read back an f32 literal as a flat vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Read a scalar f32.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .context("scalar read")
        .map_err(|e| anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(literal_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn i32_literals() {
        let l = literal_i32(&[1, 2, 3, 4], &[4]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("LCQUANT_ARTIFACTS", "/tmp/zzz_artifacts");
        assert_eq!(Engine::default_dir(), PathBuf::from("/tmp/zzz_artifacts"));
        std::env::remove_var("LCQUANT_ARTIFACTS");
        assert_eq!(Engine::default_dir(), PathBuf::from("artifacts"));
    }
}
