//! Config system: JSON experiment/run configuration with CLI overrides.
//!
//! A run config fully determines a training + quantization run (net
//! architecture, data, schedules, scheme), so experiments are reproducible
//! from a single file. See `configs/*.json` in the repo root.

use crate::coordinator::{LcConfig, MuSchedule, PenaltyMode};
use crate::nn::sgd::ClippedLrSchedule;
use crate::nn::{Activation, MlpSpec};
use crate::quant::Scheme;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Top-level run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub name: String,
    pub net: MlpSpec,
    pub data: DataConfig,
    pub train: TrainConfig,
    pub lc: LcConfig,
    pub serve: ServeSettings,
    pub net_serve: NetSettings,
    pub fabric: FabricSettings,
    pub obs: ObsSettings,
    pub seed: u64,
}

/// One shard of the serve fabric (`"shards"` array entries inside
/// `serve.fabric`): which models it owns and the replica addresses that
/// can answer for them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSettings {
    /// Model names this shard owns (empty = wildcard: route by the
    /// replica's hello catalog).
    pub models: Vec<String>,
    /// Backend replica addresses (`host:port`) serving this shard.
    pub replicas: Vec<String>,
}

/// Router-tier knobs (`"fabric"` object inside the `"serve"` section):
/// the static shard map plus failover/health policy for the router
/// process. See `docs/FABRIC.md` for semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct FabricSettings {
    /// Shard map; empty means the router has no backends (every request
    /// sheds `UnknownModel`/`Overloaded`).
    pub shards: Vec<ShardSettings>,
    /// Forward attempts per request before shedding `Overloaded`
    /// (clamped to >= 1).
    pub retry_budget: usize,
    /// Per-request wall-clock deadline in milliseconds; exceeding it
    /// sheds a typed `Timeout` error.
    pub deadline_ms: f64,
    /// Decorrelated-jitter backoff floor between retries, milliseconds
    /// (0 with a 0 cap disables backoff sleeps).
    pub backoff_base_ms: f64,
    /// Backoff ceiling, milliseconds.
    pub backoff_cap_ms: f64,
    /// Active hello-probe period, milliseconds (0 disables the prober;
    /// `Down` backends then only recover via operator restart).
    pub probe_every_ms: f64,
    /// Backend dial timeout, milliseconds.
    pub connect_timeout_ms: f64,
    /// Seed for backoff jitter (per-request streams derive from it).
    pub seed: u64,
}

impl Default for FabricSettings {
    fn default() -> FabricSettings {
        let d = crate::net::FabricConfig::default();
        FabricSettings {
            shards: Vec::new(),
            retry_budget: d.retry_budget,
            deadline_ms: d.deadline.as_secs_f64() * 1e3,
            backoff_base_ms: d.backoff.base.as_secs_f64() * 1e3,
            backoff_cap_ms: d.backoff.cap.as_secs_f64() * 1e3,
            probe_every_ms: d.probe_every.as_secs_f64() * 1e3,
            connect_timeout_ms: d.connect_timeout.as_secs_f64() * 1e3,
            seed: d.seed,
        }
    }
}

impl FabricSettings {
    /// Lower into the runtime [`crate::net::FabricConfig`].
    pub fn to_fabric_config(&self) -> crate::net::FabricConfig {
        let ms = |v: f64| std::time::Duration::from_secs_f64(v.max(0.0) / 1e3);
        crate::net::FabricConfig {
            shards: self
                .shards
                .iter()
                .map(|s| crate::net::ShardConfig {
                    models: s.models.clone(),
                    replicas: s.replicas.clone(),
                })
                .collect(),
            retry_budget: self.retry_budget.max(1),
            deadline: ms(self.deadline_ms),
            backoff: crate::util::backoff::BackoffCfg {
                base: ms(self.backoff_base_ms),
                cap: ms(self.backoff_cap_ms),
            },
            probe_every: ms(self.probe_every_ms),
            connect_timeout: ms(self.connect_timeout_ms),
            seed: self.seed,
        }
    }
}

/// Observability knobs (`"obs"` section): whether the process mirrors its
/// per-server stats into the global metrics registry, how many trace slots
/// the serving plane rings through, and how often long-running serve
/// processes dump a registry snapshot to stderr.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsSettings {
    /// Master switch for global-registry mirroring and request tracing
    /// (per-server stats always record).
    pub enabled: bool,
    /// Trace-ring capacity per network server (rounded up to a power of
    /// two; overwrite-oldest).
    pub trace_slots: usize,
    /// Seconds between periodic snapshot dumps while serving
    /// (0 = never dump).
    pub snapshot_every_s: f64,
    /// Samples retained by windowed-rate consumers such as `lcquant top`
    /// (an [`crate::obs::RateWindow`] holds this many periodic snapshots;
    /// minimum 2 — rates need a delta).
    pub window_slots: usize,
}

impl Default for ObsSettings {
    fn default() -> ObsSettings {
        ObsSettings { enabled: true, trace_slots: 256, snapshot_every_s: 0.0, window_slots: 16 }
    }
}

/// Network serving knobs (`"net"` object inside the `"serve"` section —
/// the top-level `"net"` key already names the MLP architecture): where
/// the LCQ-RPC listener binds and how much concurrency it admits before
/// shedding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetSettings {
    /// Listen address (`host:port`; port 0 = ephemeral).
    pub bind_addr: String,
    /// Concurrent connections served across the net threads (plus a
    /// same-sized accept backlog); beyond that, handshakes are shed.
    pub max_connections: usize,
    /// Event-loop (net) threads multiplexing the connections.
    pub net_threads: usize,
    /// Per-connection pipeline bound: requests in flight plus replies
    /// queued for write before the connection is shed.
    pub max_inflight: usize,
    /// In-flight request budget, in rows; excess is shed with an
    /// `Overloaded` error frame.
    pub inflight_budget: usize,
}

impl Default for NetSettings {
    fn default() -> NetSettings {
        NetSettings {
            bind_addr: "127.0.0.1:7070".into(),
            max_connections: 64,
            net_threads: 2,
            max_inflight: 8,
            inflight_budget: 256,
        }
    }
}

impl NetSettings {
    pub fn to_net_config(&self) -> crate::net::NetConfig {
        crate::net::NetConfig {
            bind_addr: self.bind_addr.clone(),
            max_connections: self.max_connections,
            net_threads: self.net_threads,
            max_inflight: self.max_inflight,
            inflight_budget: self.inflight_budget,
            ..crate::net::NetConfig::default()
        }
    }

    /// Like [`NetSettings::to_net_config`], but sized by the run's
    /// observability settings (trace-ring capacity).
    pub fn to_net_config_with_obs(&self, obs: &ObsSettings) -> crate::net::NetConfig {
        let mut cfg = self.to_net_config();
        cfg.trace_slots = obs.trace_slots.max(2);
        cfg
    }
}

/// Micro-batching and pipelining knobs for the serving subsystem
/// (`"serve"` section).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSettings {
    pub max_batch: usize,
    pub max_wait_ms: f64,
    /// Executor threads running coalesced batches concurrently (the serve
    /// pipeline depth; batches overlap on the multi-task worker pool).
    pub pipeline_depth: usize,
    /// Client threads the `serve-smoke` CLI drives traffic with.
    pub smoke_clients: usize,
    /// Engine execution tier (`"auto"` | `"lut"` | `"bitsliced"`): which
    /// kernels layer passes run on — bit-sliced plane kernels wherever
    /// possible (auto, the default), or a forced tier for A/B comparison.
    pub engine_mode: crate::serve::EngineMode,
}

impl Default for ServeSettings {
    fn default() -> ServeSettings {
        ServeSettings {
            max_batch: 64,
            max_wait_ms: 2.0,
            pipeline_depth: 2,
            smoke_clients: 8,
            engine_mode: crate::serve::EngineMode::Auto,
        }
    }
}

impl ServeSettings {
    pub fn to_server_config(&self) -> crate::serve::ServerConfig {
        crate::serve::ServerConfig {
            max_batch: self.max_batch,
            max_wait: std::time::Duration::from_secs_f64(self.max_wait_ms / 1e3),
            pipeline_depth: self.pipeline_depth,
        }
    }
}

#[derive(Clone, Debug)]
pub struct DataConfig {
    /// "synth_mnist" or "cifar_like".
    pub kind: String,
    pub n: usize,
    pub test_frac: f64,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// SGD steps to train the reference net.
    pub ref_steps: usize,
    pub batch: usize,
    pub lr0: f32,
    pub lr_decay: f32,
    pub momentum: f32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "lenet300-k2".into(),
            net: MlpSpec::lenet300(),
            data: DataConfig { kind: "synth_mnist".into(), n: 2000, test_frac: 0.1 },
            train: TrainConfig { ref_steps: 800, batch: 128, lr0: 0.1, lr_decay: 0.99, momentum: 0.95 },
            lc: LcConfig::default(),
            serve: ServeSettings::default(),
            net_serve: NetSettings::default(),
            fabric: FabricSettings::default(),
            obs: ObsSettings::default(),
            seed: 42,
        }
    }
}

/// Parse a quantization scheme from a string like `adaptive:4`, `binary`,
/// `binary_scale`, `ternary`, `ternary_scale`, `pow2:4`, `fixed:-1,0,1`.
pub fn parse_scheme(s: &str) -> Result<Scheme> {
    let (head, arg) = match s.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (s, None),
    };
    Ok(match head {
        "adaptive" => Scheme::AdaptiveCodebook {
            k: arg
                .ok_or_else(|| anyhow!("adaptive:K requires K"))?
                .parse()
                .context("bad K")?,
        },
        "adaptive_zero" => Scheme::AdaptiveWithZero {
            k: arg
                .ok_or_else(|| anyhow!("adaptive_zero:K requires K"))?
                .parse()
                .context("bad K")?,
        },
        "binary" => Scheme::Binary,
        "binary_scale" => Scheme::BinaryScale,
        "ternary" => Scheme::Ternary,
        "ternary_scale" => Scheme::TernaryScale,
        "pow2" => Scheme::PowersOfTwo {
            c: arg.ok_or_else(|| anyhow!("pow2:C requires C"))?.parse().context("bad C")?,
        },
        "fixed" => Scheme::FixedCodebook {
            codebook: arg
                .ok_or_else(|| anyhow!("fixed:v1,v2,... requires values"))?
                .split(',')
                .map(|v| v.trim().parse::<f32>().context("bad codebook value"))
                .collect::<Result<Vec<_>>>()?,
        },
        _ => bail!("unknown scheme '{s}'"),
    })
}

fn get_f(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
}
fn get_u(j: &Json, key: &str, default: usize) -> usize {
    j.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
}
fn get_s<'a>(j: &'a Json, key: &str, default: &'a str) -> &'a str {
    j.get(key).and_then(|v| v.as_str()).unwrap_or(default)
}
fn get_b(j: &Json, key: &str, default: bool) -> bool {
    j.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
}
fn get_str_arr(j: &Json, key: &str) -> Vec<String> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_str()).map(str::to_string).collect())
        .unwrap_or_default()
}

impl RunConfig {
    /// Parse from JSON text; missing fields fall back to defaults.
    pub fn from_json(text: &str) -> Result<RunConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let d = RunConfig::default();

        let net = match j.get("net") {
            Some(n) => {
                let sizes: Vec<usize> = n
                    .get("sizes")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_else(|| d.net.sizes.clone());
                let act = match get_s(n, "activation", "tanh") {
                    "relu" => Activation::Relu,
                    _ => Activation::Tanh,
                };
                let dropout: Vec<f32> = n
                    .get("dropout_keep")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|f| f as f32).collect())
                    .unwrap_or_default();
                MlpSpec { sizes, hidden_activation: act, dropout_keep: dropout }
            }
            None => d.net.clone(),
        };

        let data = match j.get("data") {
            Some(n) => DataConfig {
                kind: get_s(n, "kind", &d.data.kind).to_string(),
                n: get_u(n, "n", d.data.n),
                test_frac: get_f(n, "test_frac", d.data.test_frac),
            },
            None => d.data.clone(),
        };

        let train = match j.get("train") {
            Some(n) => TrainConfig {
                ref_steps: get_u(n, "ref_steps", d.train.ref_steps),
                batch: get_u(n, "batch", d.train.batch),
                lr0: get_f(n, "lr0", d.train.lr0 as f64) as f32,
                lr_decay: get_f(n, "lr_decay", d.train.lr_decay as f64) as f32,
                momentum: get_f(n, "momentum", d.train.momentum as f64) as f32,
            },
            None => d.train.clone(),
        };

        let lc = match j.get("lc") {
            Some(n) => LcConfig {
                scheme: parse_scheme(get_s(n, "scheme", "adaptive:2"))?,
                mu: MuSchedule::new(
                    get_f(n, "mu0", 9.76e-5) as f32,
                    get_f(n, "mu_mult", 1.1) as f32,
                ),
                iterations: get_u(n, "iterations", 30),
                l_steps: get_u(n, "l_steps", 200),
                lr: ClippedLrSchedule {
                    eta0: get_f(n, "lr0", 0.1) as f32,
                    decay: get_f(n, "lr_decay", 0.99) as f32,
                },
                momentum: get_f(n, "momentum", 0.95) as f32,
                mode: match get_s(n, "penalty", "augmented_lagrangian") {
                    "quadratic" => PenaltyMode::QuadraticPenalty,
                    _ => PenaltyMode::AugmentedLagrangian,
                },
                tol: get_f(n, "tol", 1e-4) as f32,
                seed: get_u(n, "seed", 0) as u64,
                eval_every: get_u(n, "eval_every", 1),
                n_weight_samples: get_u(n, "n_weight_samples", 0),
            },
            None => d.lc.clone(),
        };

        let serve = match j.get("serve") {
            Some(n) => ServeSettings {
                max_batch: get_u(n, "max_batch", d.serve.max_batch),
                max_wait_ms: get_f(n, "max_wait_ms", d.serve.max_wait_ms),
                pipeline_depth: get_u(n, "pipeline_depth", d.serve.pipeline_depth).max(1),
                smoke_clients: get_u(n, "smoke_clients", d.serve.smoke_clients).max(1),
                engine_mode: get_s(n, "engine_mode", d.serve.engine_mode.name()).parse()?,
            },
            None => d.serve.clone(),
        };

        let net_serve = match j.get("serve").and_then(|s| s.get("net")) {
            Some(n) => NetSettings {
                bind_addr: get_s(n, "bind_addr", &d.net_serve.bind_addr).to_string(),
                max_connections: get_u(n, "max_connections", d.net_serve.max_connections)
                    .max(1),
                net_threads: get_u(n, "net_threads", d.net_serve.net_threads).max(1),
                max_inflight: get_u(n, "max_inflight", d.net_serve.max_inflight).max(1),
                inflight_budget: get_u(n, "inflight_budget", d.net_serve.inflight_budget)
                    .max(1),
            },
            None => d.net_serve.clone(),
        };

        let fabric = match j.get("serve").and_then(|s| s.get("fabric")) {
            Some(n) => FabricSettings {
                shards: n
                    .get("shards")
                    .and_then(|v| v.as_arr())
                    .map(|a| {
                        a.iter()
                            .map(|s| ShardSettings {
                                models: get_str_arr(s, "models"),
                                replicas: get_str_arr(s, "replicas"),
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                retry_budget: get_u(n, "retry_budget", d.fabric.retry_budget).max(1),
                deadline_ms: get_f(n, "deadline_ms", d.fabric.deadline_ms).max(0.0),
                backoff_base_ms: get_f(n, "backoff_base_ms", d.fabric.backoff_base_ms)
                    .max(0.0),
                backoff_cap_ms: get_f(n, "backoff_cap_ms", d.fabric.backoff_cap_ms).max(0.0),
                probe_every_ms: get_f(n, "probe_every_ms", d.fabric.probe_every_ms).max(0.0),
                connect_timeout_ms: get_f(n, "connect_timeout_ms", d.fabric.connect_timeout_ms)
                    .max(0.0),
                seed: get_u(n, "seed", d.fabric.seed as usize) as u64,
            },
            None => d.fabric.clone(),
        };

        let obs = match j.get("obs") {
            Some(n) => ObsSettings {
                enabled: get_b(n, "enabled", d.obs.enabled),
                trace_slots: get_u(n, "trace_slots", d.obs.trace_slots).max(2),
                snapshot_every_s: get_f(n, "snapshot_every_s", d.obs.snapshot_every_s).max(0.0),
                window_slots: get_u(n, "window_slots", d.obs.window_slots).max(2),
            },
            None => d.obs.clone(),
        };

        Ok(RunConfig {
            name: get_s(&j, "name", &d.name).to_string(),
            net,
            data,
            train,
            lc,
            serve,
            net_serve,
            fabric,
            obs,
            seed: get_u(&j, "seed", d.seed as usize) as u64,
        })
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        RunConfig::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        assert_eq!(parse_scheme("adaptive:8").unwrap(), Scheme::AdaptiveCodebook { k: 8 });
        assert_eq!(parse_scheme("binary").unwrap(), Scheme::Binary);
        assert_eq!(parse_scheme("binary_scale").unwrap(), Scheme::BinaryScale);
        assert_eq!(parse_scheme("pow2:3").unwrap(), Scheme::PowersOfTwo { c: 3 });
        assert_eq!(
            parse_scheme("fixed:-1,0,1").unwrap(),
            Scheme::FixedCodebook { codebook: vec![-1.0, 0.0, 1.0] }
        );
        assert!(parse_scheme("bogus").is_err());
        assert!(parse_scheme("adaptive").is_err());
    }

    #[test]
    fn full_json_config() {
        let text = r#"{
            "name": "test-run",
            "seed": 7,
            "net": {"sizes": [784, 50, 10], "activation": "relu"},
            "data": {"kind": "synth_mnist", "n": 500, "test_frac": 0.2},
            "train": {"ref_steps": 100, "batch": 64, "lr0": 0.05},
            "lc": {"scheme": "adaptive:4", "mu0": 0.001, "iterations": 10, "penalty": "quadratic"}
        }"#;
        let c = RunConfig::from_json(text).unwrap();
        assert_eq!(c.name, "test-run");
        assert_eq!(c.seed, 7);
        assert_eq!(c.net.sizes, vec![784, 50, 10]);
        assert_eq!(c.net.hidden_activation, Activation::Relu);
        assert_eq!(c.data.n, 500);
        assert_eq!(c.train.batch, 64);
        assert_eq!(c.lc.scheme, Scheme::AdaptiveCodebook { k: 4 });
        assert_eq!(c.lc.mode, PenaltyMode::QuadraticPenalty);
        assert_eq!(c.lc.iterations, 10);
    }

    #[test]
    fn empty_json_gives_defaults() {
        let c = RunConfig::from_json("{}").unwrap();
        assert_eq!(c.net.sizes, vec![784, 300, 100, 10]);
        assert_eq!(c.lc.iterations, 30);
        assert_eq!(c.serve, ServeSettings::default());
    }

    #[test]
    fn serve_section_parses() {
        let c = RunConfig::from_json(
            r#"{"serve": {"max_batch": 8, "max_wait_ms": 0.5, "pipeline_depth": 4,
                          "smoke_clients": 3}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.max_batch, 8);
        assert_eq!(c.serve.max_wait_ms, 0.5);
        assert_eq!(c.serve.pipeline_depth, 4);
        assert_eq!(c.serve.smoke_clients, 3);
        assert_eq!(c.serve.engine_mode, crate::serve::EngineMode::Auto);
        let sc = c.serve.to_server_config();
        assert_eq!(sc.max_batch, 8);
        assert_eq!(sc.max_wait, std::time::Duration::from_micros(500));
        assert_eq!(sc.pipeline_depth, 4);
        // omitted -> defaults; zero depth clamps to 1
        let d = RunConfig::from_json(r#"{"serve": {"max_batch": 4}}"#).unwrap();
        assert_eq!(d.serve.smoke_clients, 8);
        assert_eq!(d.serve.pipeline_depth, 2);
        let z = RunConfig::from_json(r#"{"serve": {"pipeline_depth": 0}}"#).unwrap();
        assert_eq!(z.serve.pipeline_depth, 1);
    }

    #[test]
    fn engine_mode_parses() {
        for (s, want) in [
            ("auto", crate::serve::EngineMode::Auto),
            ("lut", crate::serve::EngineMode::Lut),
            ("bitsliced", crate::serve::EngineMode::BitSliced),
        ] {
            let c = RunConfig::from_json(&format!(r#"{{"serve": {{"engine_mode": "{s}"}}}}"#))
                .unwrap();
            assert_eq!(c.serve.engine_mode, want);
        }
        assert!(RunConfig::from_json(r#"{"serve": {"engine_mode": "xnor"}}"#).is_err());
    }

    #[test]
    fn net_section_parses() {
        // the network knobs nest under "serve" (top-level "net" is the
        // MLP architecture) and coexist with the batching knobs
        let c = RunConfig::from_json(
            r#"{"net": {"sizes": [4, 2]},
                "serve": {"max_batch": 8,
                          "net": {"bind_addr": "0.0.0.0:9000", "max_connections": 16,
                                  "net_threads": 3, "max_inflight": 4,
                                  "inflight_budget": 32}}}"#,
        )
        .unwrap();
        assert_eq!(c.net.sizes, vec![4, 2]);
        assert_eq!(c.serve.max_batch, 8);
        assert_eq!(c.net_serve.bind_addr, "0.0.0.0:9000");
        assert_eq!(c.net_serve.max_connections, 16);
        assert_eq!(c.net_serve.net_threads, 3);
        assert_eq!(c.net_serve.max_inflight, 4);
        assert_eq!(c.net_serve.inflight_budget, 32);
        let nc = c.net_serve.to_net_config();
        assert_eq!(nc.bind_addr, "0.0.0.0:9000");
        assert_eq!(nc.max_connections, 16);
        assert_eq!(nc.net_threads, 3);
        assert_eq!(nc.max_inflight, 4);
        assert_eq!(nc.inflight_budget, 32);
        // omitted -> defaults; zero knobs clamp to 1
        let d = RunConfig::from_json("{}").unwrap();
        assert_eq!(d.net_serve, NetSettings::default());
        let z = RunConfig::from_json(
            r#"{"serve": {"net": {"max_connections": 0, "net_threads": 0,
                                  "max_inflight": 0, "inflight_budget": 0}}}"#,
        )
        .unwrap();
        assert_eq!(z.net_serve.max_connections, 1);
        assert_eq!(z.net_serve.net_threads, 1);
        assert_eq!(z.net_serve.max_inflight, 1);
        assert_eq!(z.net_serve.inflight_budget, 1);
    }

    #[test]
    fn obs_section_parses() {
        let c = RunConfig::from_json(
            r#"{"obs": {"enabled": false, "trace_slots": 64, "snapshot_every_s": 2.5,
                 "window_slots": 8}}"#,
        )
        .unwrap();
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.trace_slots, 64);
        assert_eq!(c.obs.snapshot_every_s, 2.5);
        assert_eq!(c.obs.window_slots, 8);
        // the trace ring feeds the net config
        let nc = c.net_serve.to_net_config_with_obs(&c.obs);
        assert_eq!(nc.trace_slots, 64);
        // omitted -> defaults; degenerate knobs clamp
        let d = RunConfig::from_json("{}").unwrap();
        assert_eq!(d.obs, ObsSettings::default());
        assert!(d.obs.enabled);
        let z = RunConfig::from_json(
            r#"{"obs": {"trace_slots": 0, "snapshot_every_s": -1.0, "window_slots": 1}}"#,
        )
        .unwrap();
        assert_eq!(z.obs.trace_slots, 2);
        assert_eq!(z.obs.snapshot_every_s, 0.0);
        assert_eq!(z.obs.window_slots, 2);
    }

    #[test]
    fn fabric_section_parses() {
        let c = RunConfig::from_json(
            r#"{"serve": {"fabric": {
                  "shards": [
                    {"models": ["lenet300-k2"], "replicas": ["127.0.0.1:7071", "127.0.0.1:7072"]},
                    {"replicas": ["127.0.0.1:7073"]}
                  ],
                  "retry_budget": 6, "deadline_ms": 250.0,
                  "backoff_base_ms": 2.0, "backoff_cap_ms": 20.0,
                  "probe_every_ms": 0, "connect_timeout_ms": 100.0, "seed": 9}}}"#,
        )
        .unwrap();
        assert_eq!(c.fabric.shards.len(), 2);
        assert_eq!(c.fabric.shards[0].models, vec!["lenet300-k2".to_string()]);
        assert_eq!(c.fabric.shards[0].replicas.len(), 2);
        // omitted models array = wildcard shard
        assert!(c.fabric.shards[1].models.is_empty());
        assert_eq!(c.fabric.retry_budget, 6);
        assert_eq!(c.fabric.seed, 9);
        let fc = c.fabric.to_fabric_config();
        assert_eq!(fc.shards.len(), 2);
        assert_eq!(fc.retry_budget, 6);
        assert_eq!(fc.deadline, std::time::Duration::from_millis(250));
        assert_eq!(fc.backoff.base, std::time::Duration::from_millis(2));
        assert_eq!(fc.backoff.cap, std::time::Duration::from_millis(20));
        // probe_every_ms 0 disables the prober
        assert!(fc.probe_every.is_zero());
        assert_eq!(fc.connect_timeout, std::time::Duration::from_millis(100));
        assert_eq!(fc.seed, 9);
        // omitted -> defaults mirror the runtime defaults
        let d = RunConfig::from_json("{}").unwrap();
        assert_eq!(d.fabric, FabricSettings::default());
        let dc = d.fabric.to_fabric_config();
        let rt = crate::net::FabricConfig::default();
        assert_eq!(dc.retry_budget, rt.retry_budget);
        assert_eq!(dc.deadline, rt.deadline);
        assert_eq!(dc.probe_every, rt.probe_every);
        // degenerate retry budget clamps to 1
        let z = RunConfig::from_json(r#"{"serve": {"fabric": {"retry_budget": 0}}}"#).unwrap();
        assert_eq!(z.fabric.to_fabric_config().retry_budget, 1);
    }

    #[test]
    fn bad_json_rejected() {
        assert!(RunConfig::from_json("{not json").is_err());
        assert!(RunConfig::from_json(r#"{"lc": {"scheme": "nope"}}"#).is_err());
    }
}
