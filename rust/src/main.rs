//! `lcquant` CLI — launcher for the LC quantization system.
//!
//! ```text
//! lcquant experiment <id|all> [--out results] [--scale quick|full] [--seed N]
//! lcquant run --config configs/lenet300_k2.json [--out results]
//! lcquant pack --config configs/lenet300_k2.json [--out models]
//! lcquant serve-smoke --models models [--requests N] [--clients N] [--depth N] [--config FILE]
//! lcquant serve-net --models models [--addr HOST:PORT] [--depth N] [--config FILE]
//!                   [--smoke-requests N [--connections N] [--model NAME]]
//! lcquant serve-fabric --models DIR [--addr HOST:PORT] [--config FILE] [--smoke-backends N]
//!                      [--smoke-requests N [--connections N] [--model NAME]
//!                       [--kill-backend-at N] [--restart-backend-at N]]
//! lcquant client-smoke --addr HOST:PORT [--requests N] [--connections N] [--model NAME] [--batch N]
//! lcquant stats --addr HOST:PORT
//! lcquant top --addr HOST:PORT [--interval S] [--iters N] [--window N]
//! lcquant pjrt-smoke [--artifacts artifacts]
//! lcquant list
//! ```

use anyhow::{anyhow, Result};
use lcquant::config::RunConfig;
use lcquant::coordinator::{lc_quantize, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::experiments::{self, Scale};
use lcquant::nn::Mlp;
use lcquant::util::cli::Args;
use lcquant::util::log::{set_level, Level};
use lcquant::util::rng::Rng;

fn usage() -> ! {
    eprintln!(
        "usage:
  lcquant experiment <id|all> [--out DIR] [--scale quick|full] [--seed N]
      ids: {:?}
  lcquant run --config FILE [--out DIR]
  lcquant pack --config FILE [--out DIR]
  lcquant serve-smoke --models DIR [--requests N] [--clients N] [--depth N] [--config FILE]
  lcquant serve-net --models DIR [--addr HOST:PORT] [--depth N] [--config FILE]
                    [--smoke-requests N [--connections N] [--model NAME]]
  lcquant serve-fabric --models DIR [--addr HOST:PORT] [--config FILE] [--smoke-backends N]
                       [--smoke-requests N [--connections N] [--model NAME]
                        [--kill-backend-at N] [--restart-backend-at N]]
  lcquant client-smoke --addr HOST:PORT [--requests N] [--connections N] [--model NAME] [--batch N]
  lcquant stats --addr HOST:PORT
  lcquant top --addr HOST:PORT [--interval S] [--iters N] [--window N]
  lcquant pjrt-smoke [--artifacts DIR]
  lcquant list",
        experiments::ALL
    );
    std::process::exit(2);
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let out = args.get_or("out", "results");
    let scale = Scale::from_str(args.get_or("scale", "quick"));
    let seed = args.get_u64("seed", 42);
    std::fs::create_dir_all(out)?;
    experiments::run(id, out, scale, seed)
}

/// Train the reference net per the config's train section: chunked SGD
/// with the decayed learning-rate schedule. Shared by `run` and `pack` so
/// both produce the same reference net from the same config.
fn train_reference(
    backend: &mut dyn lcquant::coordinator::Backend,
    train: &lcquant::config::TrainConfig,
) {
    use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
    use lcquant::coordinator::Backend as _;
    let mut opt = FlatNesterov::new(backend.layout(), train.momentum);
    let chunk = 100usize;
    let mut step = 0;
    while step < train.ref_steps {
        let n = chunk.min(train.ref_steps - step);
        let lr = train.lr0 * train.lr_decay.powi((step / chunk) as i32);
        run_sgd(backend, &mut opt, n, lr, None);
        step += n;
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    use lcquant::coordinator::Backend;
    let cfg_path = args
        .get("config")
        .ok_or_else(|| anyhow!("run requires --config FILE"))?;
    let cfg = RunConfig::from_file(cfg_path)?;
    lcquant::info!("config '{}' loaded from {cfg_path}", cfg.name);

    let mut data = match cfg.data.kind.as_str() {
        "cifar_like" => lcquant::data::cifar_like::generate(cfg.data.n, cfg.seed),
        _ => SynthMnist::generate(cfg.data.n, cfg.seed),
    };
    data.subtract_mean(None);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let (train, test) = data.split(cfg.data.test_frac, &mut rng);

    // --backend pjrt runs the L step through the AOT artifact (requires
    // `make artifacts` and a net matching the artifact's architecture);
    // default is the pure-rust backend.
    let mut backend: Box<dyn Backend> = match args.get_or("backend", "native") {
        "pjrt" => pjrt_backend(args, train, test, cfg.seed)?,
        _ => {
            let net = Mlp::new(&cfg.net, cfg.seed);
            Box::new(NativeBackend::new(net, train, Some(test), cfg.train.batch, cfg.seed))
        }
    };
    let backend = backend.as_mut();

    // train the reference
    train_reference(backend, &cfg.train);
    let (rl, re) = backend.eval_train();
    lcquant::info!("reference: loss={rl:.5} err={re:.2}%");

    let res = lc_quantize(backend, &cfg.lc);
    println!(
        "LC done [{}]: quantized train loss {:.5}, train err {:.2}%, test err {:?}",
        cfg.lc.scheme.label(),
        res.train_loss,
        res.train_err,
        res.test_err
    );
    for (l, cb) in res.codebooks.iter().enumerate() {
        println!("  layer {} codebook: {:?}", l + 1, cb);
    }
    // persist history
    let out = args.get_or("out", "results");
    let mut hist = lcquant::metrics::History::new(&["iter", "mu", "lstep_loss", "feasibility"]);
    for r in &res.history {
        hist.push(vec![r.iter as f64, r.mu as f64, r.lstep_loss as f64, r.feasibility as f64]);
    }
    hist.save_csv(&std::path::Path::new(out).join(format!("{}_history.csv", cfg.name)))?;
    Ok(())
}

/// Train + LC-quantize per the config, then pack the result into a
/// deployable `.lcq` artifact (the compressed bits, not the dense weights).
fn cmd_pack(args: &Args) -> Result<()> {
    use lcquant::coordinator::Backend;
    use lcquant::serve::PackedModel;
    let cfg_path = args
        .get("config")
        .ok_or_else(|| anyhow!("pack requires --config FILE"))?;
    let cfg = RunConfig::from_file(cfg_path)?;
    let mut data = match cfg.data.kind.as_str() {
        "cifar_like" => lcquant::data::cifar_like::generate(cfg.data.n, cfg.seed),
        _ => SynthMnist::generate(cfg.data.n, cfg.seed),
    };
    data.subtract_mean(None);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let (train, test) = data.split(cfg.data.test_frac, &mut rng);
    let net = Mlp::new(&cfg.net, cfg.seed);
    let mut backend = NativeBackend::new(net, train, Some(test), cfg.train.batch, cfg.seed);
    train_reference(&mut backend, &cfg.train);
    let res = lc_quantize(&mut backend, &cfg.lc);
    let model = PackedModel::from_lc(&cfg.name, &cfg.net, &res, backend.params())?;
    let out = std::path::Path::new(args.get_or("out", "models"))
        .join(format!("{}.lcq", cfg.name));
    model.save(&out)?;
    println!(
        "packed '{}' [{}]: train err {:.2}%, ρ = ×{:.1} ({} bytes) → {out:?}",
        cfg.name,
        res.scheme.label(),
        res.train_err,
        model.compression_ratio(),
        model.payload_bits().div_ceil(8),
    );
    Ok(())
}

/// Load a directory of packed models and push random traffic through the
/// micro-batching server — an in-process serving smoke test. Batching
/// knobs come from the optional `--config` file's `"serve"` section.
fn cmd_serve_smoke(args: &Args) -> Result<()> {
    use lcquant::serve::{MicroBatchServer, Registry};
    use std::sync::Arc;
    let dir = std::path::PathBuf::from(
        args.get("models").ok_or_else(|| anyhow!("serve-smoke requires --models DIR"))?,
    );
    let mut serve_cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?.serve,
        None => lcquant::config::ServeSettings::default(),
    };
    // --depth N overrides the config's serve.pipeline_depth (number of
    // concurrent batch executors; batches overlap on the multi-task pool)
    serve_cfg.pipeline_depth = args.get_usize("depth", serve_cfg.pipeline_depth).max(1);
    // zero-copy mmap load; engine tier from serve.engine_mode (default auto)
    let registry = Arc::new(Registry::load_dir_with(&dir, serve_cfg.engine_mode)?);
    let names = registry.names();
    println!(
        "serving {} model(s): {names:?} (max_batch {}, max_wait {}ms, pipeline depth {}, \
         {} client threads)",
        registry.len(),
        serve_cfg.max_batch,
        serve_cfg.max_wait_ms,
        serve_cfg.pipeline_depth,
        serve_cfg.smoke_clients,
    );
    let n_requests = args.get_usize("requests", 256).max(1);
    let server = MicroBatchServer::start(Arc::clone(&registry), serve_cfg.to_server_config());
    // client count comes from the config's "serve" section
    // (`smoke_clients`), overridable with --clients N; clients are blocking
    // request drivers, so they fan out on scoped threads (pool::run_scoped)
    // and leave the worker pool free for the engine they exercise
    let n_threads = args.get_usize("clients", serve_cfg.smoke_clients).max(1);
    let clients: Vec<lcquant::serve::Client> =
        (0..n_threads).map(|_| server.client()).collect();
    let t = lcquant::util::timer::Timer::start();
    lcquant::linalg::pool::run_scoped(n_threads, |th| {
        let client = &clients[th];
        let mut rng = Rng::new(1000 + th as u64);
        // spread the remainder so exactly n_requests are sent
        let quota = n_requests / n_threads + usize::from(th < n_requests % n_threads);
        for i in 0..quota {
            let name = &names[(th + i) % names.len()];
            let in_dim = registry.get(name).unwrap().engine.in_dim();
            let mut x = vec![0.0f32; in_dim];
            rng.fill_normal(&mut x, 0.0, 1.0);
            client.infer(name, x).expect("inference failed");
        }
    });
    let elapsed = t.elapsed_s();
    let mut server = server;
    server.stop();
    let stats = server.stats();
    println!(
        "{} requests in {elapsed:.2}s ({:.0} req/s): p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms, \
         mean batch {:.1}",
        stats.requests,
        stats.requests as f64 / elapsed,
        stats.p50_ms,
        stats.p90_ms,
        stats.p99_ms,
        stats.mean_batch,
    );
    println!("serve-smoke OK");
    Ok(())
}

/// Serve a directory of packed models over LCQ-RPC (framed TCP). With
/// `--smoke-requests N` the command also drives its own loopback load
/// generator and exits (a self-contained pack → serve → round-trip demo);
/// without it, the server runs until the process is killed.
fn cmd_serve_net(args: &Args) -> Result<()> {
    use lcquant::net::{loadgen, LoadGenConfig, NetServer};
    use lcquant::serve::Registry;
    use std::sync::Arc;
    let dir = std::path::PathBuf::from(
        args.get("models").ok_or_else(|| anyhow!("serve-net requires --models DIR"))?,
    );
    let (mut serve_cfg, mut net_cfg, obs_cfg) = match args.get("config") {
        Some(path) => {
            let c = RunConfig::from_file(path)?;
            (c.serve, c.net_serve, c.obs)
        }
        None => (
            lcquant::config::ServeSettings::default(),
            lcquant::config::NetSettings::default(),
            lcquant::config::ObsSettings::default(),
        ),
    };
    serve_cfg.pipeline_depth = args.get_usize("depth", serve_cfg.pipeline_depth).max(1);
    if let Some(addr) = args.get("addr") {
        net_cfg.bind_addr = addr.to_string();
    }
    lcquant::obs::set_enabled(obs_cfg.enabled);
    // zero-copy mmap load; engine tier from serve.engine_mode (default auto)
    let registry = Arc::new(Registry::load_dir_with(&dir, serve_cfg.engine_mode)?);
    let names = registry.names();
    let server = NetServer::start(
        Arc::clone(&registry),
        serve_cfg.to_server_config(),
        net_cfg.to_net_config_with_obs(&obs_cfg),
    )?;
    println!(
        "serving {} model(s) {names:?} on {} (pipeline depth {}, max {} connections, \
         in-flight budget {} rows)",
        registry.len(),
        server.local_addr(),
        serve_cfg.pipeline_depth,
        net_cfg.max_connections,
        net_cfg.inflight_budget,
    );
    let smoke = args.get_usize("smoke-requests", 0);
    if smoke == 0 {
        // serve until killed; the handler pool does all the work. With
        // `obs.snapshot_every_s` set, the main thread becomes the snapshot
        // dumper: one registry+trace JSON document to stderr per period
        // (stdout stays clean for the banner/scripting).
        let period = if obs_cfg.snapshot_every_s > 0.0 {
            std::time::Duration::from_secs_f64(obs_cfg.snapshot_every_s)
        } else {
            std::time::Duration::from_secs(3600)
        };
        loop {
            std::thread::sleep(period);
            if obs_cfg.snapshot_every_s > 0.0 {
                eprintln!("{}", server.snapshot_json());
            }
        }
    }
    let mut lg = LoadGenConfig::new(&server.local_addr().to_string());
    lg.connections = args.get_usize("connections", serve_cfg.smoke_clients).max(1);
    lg.requests_per_conn = (smoke / lg.connections).max(1);
    lg.model = args.get("model").map(String::from);
    let report = loadgen::run(&lg)?;
    println!("{}", report.summary());
    let mut server = server;
    server.stop();
    let b = server.batch_stats();
    let n = server.stats();
    println!(
        "batch plane: {} requests over {} batches (mean batch {:.1}); \
         net plane: {} connections, {} shed requests",
        b.requests, b.batches, b.mean_batch, n.connections, n.requests_shed,
    );
    if report.failed > 0 {
        return Err(anyhow!("{} requests failed", report.failed));
    }
    println!("serve-net smoke OK");
    Ok(())
}

/// Serve through the fabric router. With no shard map in the config (or
/// when `--smoke-backends N` forces it) the command spins up N in-process
/// backend replicas on ephemeral loopback ports — a self-contained
/// cluster demo. `--smoke-requests N` drives the loadgen cluster scenario
/// at the router and exits; `--kill-backend-at N` kills backend 0 when
/// the run-wide sent count reaches N (`--restart-backend-at M` brings it
/// back), printing failover counts and the latency tail.
fn cmd_serve_fabric(args: &Args) -> Result<()> {
    use lcquant::net::{loadgen, ClusterConfig, LoadGenConfig, NetServer, RouterConfig, RouterServer};
    use lcquant::serve::Registry;
    use std::sync::{Arc, Mutex};
    let dir = std::path::PathBuf::from(
        args.get("models").ok_or_else(|| anyhow!("serve-fabric requires --models DIR"))?,
    );
    let (serve_cfg, mut net_cfg, fabric_cfg, obs_cfg) = match args.get("config") {
        Some(path) => {
            let c = RunConfig::from_file(path)?;
            (c.serve, c.net_serve, c.fabric, c.obs)
        }
        None => (
            lcquant::config::ServeSettings::default(),
            lcquant::config::NetSettings::default(),
            lcquant::config::FabricSettings::default(),
            lcquant::config::ObsSettings::default(),
        ),
    };
    if let Some(addr) = args.get("addr") {
        net_cfg.bind_addr = addr.to_string();
    }
    lcquant::obs::set_enabled(obs_cfg.enabled);
    let mut fabric = fabric_cfg.to_fabric_config();

    // with no configured shard map (or --smoke-backends N), spin up an
    // in-process cluster of backend replicas on ephemeral loopback ports
    let n_backends = args.get_usize("smoke-backends", 0);
    let want_local = fabric.shards.is_empty() || n_backends > 0;
    let mut backends: Vec<Arc<Mutex<Option<NetServer>>>> = Vec::new();
    let mut backend_addrs: Vec<String> = Vec::new();
    let registry = Arc::new(Registry::load_dir_with(&dir, serve_cfg.engine_mode)?);
    if want_local {
        let n = n_backends.max(2);
        let mut backend_net = net_cfg.to_net_config_with_obs(&obs_cfg);
        backend_net.bind_addr = "127.0.0.1:0".into();
        for _ in 0..n {
            let s = NetServer::start(
                Arc::clone(&registry),
                serve_cfg.to_server_config(),
                backend_net.clone(),
            )?;
            backend_addrs.push(s.local_addr().to_string());
            backends.push(Arc::new(Mutex::new(Some(s))));
        }
        fabric.shards = vec![lcquant::net::ShardConfig {
            models: Vec::new(), // wildcard: route by hello catalog
            replicas: backend_addrs.clone(),
        }];
        println!("spun up {n} in-process backend replicas: {backend_addrs:?}");
    }

    let mut router = RouterServer::start(RouterConfig {
        net: net_cfg.to_net_config_with_obs(&obs_cfg),
        fabric,
    })?;
    println!(
        "fabric router on {} fronting {} replica(s); catalog: {:?}",
        router.local_addr(),
        router.fabric().backends().len(),
        router.fabric().merged_catalog().iter().map(|m| m.name.clone()).collect::<Vec<_>>(),
    );

    let smoke = args.get_usize("smoke-requests", 0);
    if smoke == 0 {
        let period = if obs_cfg.snapshot_every_s > 0.0 {
            std::time::Duration::from_secs_f64(obs_cfg.snapshot_every_s)
        } else {
            std::time::Duration::from_secs(3600)
        };
        loop {
            std::thread::sleep(period);
            if obs_cfg.snapshot_every_s > 0.0 {
                eprintln!("{}", router.snapshot_json());
            }
        }
    }

    let mut lg = LoadGenConfig::new(&router.local_addr().to_string());
    lg.connections = args.get_usize("connections", serve_cfg.smoke_clients).max(1);
    lg.requests_per_conn = (smoke / lg.connections).max(1);
    lg.model = args.get("model").map(String::from);
    let cluster = ClusterConfig {
        load: lg,
        kill_at: match args.get_usize("kill-backend-at", 0) {
            0 => None,
            n => Some(n as u64),
        },
        restart_at: match args.get_usize("restart-backend-at", 0) {
            0 => None,
            n => Some(n as u64),
        },
    };
    // the kill/restart hooks target backend 0 (only meaningful for the
    // in-process cluster; against remote shards they are no-ops)
    let victim = backends.first().cloned();
    let victim_addr = backend_addrs.first().cloned();
    let victim_restart = victim.clone();
    let kill_registry = Arc::clone(&registry);
    let kill_serve = serve_cfg.clone();
    let kill_net = net_cfg.to_net_config_with_obs(&obs_cfg);
    let report = loadgen::run_cluster(
        &cluster,
        move || {
            if let Some(v) = victim {
                if let Some(mut s) = v.lock().unwrap().take() {
                    s.stop();
                }
            }
        },
        move || {
            if let (Some(v), Some(addr)) = (victim_restart, victim_addr) {
                let mut net = kill_net;
                net.bind_addr = addr; // rebind the killed replica's port
                if let Ok(s) =
                    NetServer::start(kill_registry, kill_serve.to_server_config(), net)
                {
                    *v.lock().unwrap() = Some(s);
                }
            }
        },
    )?;
    println!("{}", report.summary());
    let snap = router.stats();
    router.stop();
    for b in &backends {
        if let Some(mut s) = b.lock().unwrap().take() {
            s.stop();
        }
    }
    println!(
        "router plane: {} ok, {} failed, {} shed; {} retries, {} failovers, \
         {} health transitions, {} probes",
        snap.requests_ok,
        snap.requests_failed,
        snap.requests_shed,
        snap.retries,
        snap.failovers,
        snap.health_transitions,
        snap.probes,
    );
    if report.load.failed > 0 {
        return Err(anyhow!("{} requests failed un-typed", report.load.failed));
    }
    println!("serve-fabric smoke OK");
    Ok(())
}

/// Drive a remote LCQ-RPC server with the multi-connection load generator
/// and print latency percentiles + throughput.
fn cmd_client_smoke(args: &Args) -> Result<()> {
    use lcquant::net::{loadgen, LoadGenConfig};
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("client-smoke requires --addr HOST:PORT"))?;
    let mut lg = LoadGenConfig::new(addr);
    lg.connections = args.get_usize("connections", 4).max(1);
    let total = args.get_usize("requests", 256).max(1);
    lg.requests_per_conn = (total / lg.connections).max(1);
    lg.model = args.get("model").map(String::from);
    lg.batch = args.get_usize("batch", 1).max(1);
    lg.seed = args.get_u64("seed", 1);
    let report = loadgen::run(&lg)?;
    println!("{}", report.summary());
    if report.failed > 0 {
        return Err(anyhow!("{} requests failed", report.failed));
    }
    println!("client-smoke OK");
    Ok(())
}

/// Fetch and print a live server's observability snapshot (the v2 `Stats`
/// frame): per-server wire/batch counters, process-wide registry, pool
/// profile, and the slowest recent request traces, as one JSON document.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("stats requires --addr HOST:PORT"))?;
    let mut client = lcquant::net::NetClient::connect(addr)
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let json = client.stats().map_err(|e| anyhow!("stats request: {e}"))?;
    println!("{json}");
    Ok(())
}

/// Live fleet dashboard: poll a router's `FleetStats` frame and render a
/// refreshing terminal view — rolling req/s, shed rate and windowed p99
/// from an [`lcquant::obs::RateWindow`] over snapshot deltas, per-backend
/// health and tail latency, and the stage breakdown of the slowest recent
/// traced request anywhere in the fleet. Everything on screen derives
/// from `FleetStatsRequest` alone; the target must speak LCQ-RPC v3.
fn cmd_top(args: &Args) -> Result<()> {
    use lcquant::obs::{HistogramSnapshot, RateWindow};
    use lcquant::util::json::Json;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow!("top requires --addr HOST:PORT (a fabric router)"))?;
    let interval = args.get_f64("interval", 1.0).max(0.05);
    let iters = args.get_usize("iters", 0); // 0 = refresh until killed
    let mut client = lcquant::net::NetClient::connect(addr)
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let mut win = RateWindow::new(args.get_usize("window", 16).max(2));
    let t0 = std::time::Instant::now();
    let mut polls = 0usize;
    loop {
        let json = client.fleet_stats().map_err(|e| anyhow!("fleet stats: {e}"))?;
        let doc = Json::parse(&json).map_err(|e| anyhow!("fleet stats parse: {e:?}"))?;
        let counter = |k: &str| {
            doc.get("fleet")
                .and_then(|f| f.get("counters"))
                .and_then(|c| c.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64
        };
        let latency = doc
            .get("fleet")
            .and_then(|f| f.get("latency"))
            .and_then(HistogramSnapshot::from_json)
            .unwrap_or_else(HistogramSnapshot::empty);
        win.push(
            t0.elapsed().as_secs_f64(),
            counter("requests_ok") + counter("requests_failed"),
            counter("requests_shed"),
            latency,
        );
        polls += 1;
        render_top(addr, &doc, &win, polls);
        if iters > 0 && polls >= iters {
            break;
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
    Ok(())
}

/// Paint one `lcquant top` frame: ANSI home + clear so the view refreshes
/// in place (harmless noise when stdout is not a terminal).
fn render_top(
    addr: &str,
    doc: &lcquant::util::json::Json,
    win: &lcquant::obs::RateWindow,
    polls: usize,
) {
    use lcquant::util::json::Json;
    // walk a key path to a number, 0.0 when any hop is missing
    let num = |path: &[&str]| -> f64 {
        let mut cur = doc;
        for k in path {
            match cur.get(k) {
                Some(next) => cur = next,
                None => return 0.0,
            }
        }
        cur.as_f64().unwrap_or(0.0)
    };
    print!("\x1b[H\x1b[2J");
    println!("lcquant top — {addr} — poll #{polls}");
    println!(
        "fleet:   {:.0}/{:.0} backends answering (healthy {:.0}, suspect {:.0}, down {:.0})",
        num(&["fleet", "backends_ok"]),
        num(&["fleet", "backends_total"]),
        num(&["fleet", "health", "healthy"]),
        num(&["fleet", "health", "suspect"]),
        num(&["fleet", "health", "down"]),
    );
    match win.rates() {
        Some(r) => println!(
            "rates:   {:.1} req/s, shed {:.2}/s ({:.1}%), p99 {:.2}ms over last {:.1}s \
             ({} requests)",
            r.qps,
            r.shed_per_s,
            r.shed_rate * 100.0,
            r.p99_ms,
            r.span_s,
            r.delta_count,
        ),
        None => println!("rates:   warming up (needs a second poll)"),
    }
    println!(
        "router:  ok {:.0}, failed {:.0}, shed {:.0}; retries {:.0}, failovers {:.0}, \
         fleet-stats served {:.0}",
        num(&["router", "requests_ok"]),
        num(&["router", "requests_failed"]),
        num(&["router", "requests_shed"]),
        num(&["router", "retries"]),
        num(&["router", "failovers"]),
        num(&["router", "fleet_stats_requests"]),
    );
    println!("backends:");
    let backends = doc.get("backends").and_then(Json::as_arr).unwrap_or(&[]);
    // track the slowest traced request seen anywhere in the fleet
    let mut worst: Option<(&Json, &str)> = None;
    for b in backends {
        let baddr = b.get("addr").and_then(Json::as_str).unwrap_or("?");
        let state = b.get("state").and_then(Json::as_str).unwrap_or("?");
        let ok = b.get("ok").and_then(Json::as_bool).unwrap_or(false);
        if !ok {
            let err = b.get("error").and_then(Json::as_str).unwrap_or("no answer");
            println!("  {baddr:<21} {state:<8} — {err}");
            continue;
        }
        let stats = |path: &[&str]| -> f64 {
            let mut cur = match b.get("stats") {
                Some(s) => s,
                None => return 0.0,
            };
            for k in path {
                match cur.get(k) {
                    Some(next) => cur = next,
                    None => return 0.0,
                }
            }
            cur.as_f64().unwrap_or(0.0)
        };
        println!(
            "  {baddr:<21} {state:<8} ok {:.0}, shed {:.0}, p99 {:.2}ms, mean batch {:.1}",
            stats(&["server", "requests_ok"]),
            stats(&["server", "requests_shed"]),
            stats(&["batch", "latency", "p99"]),
            stats(&["batch", "mean_batch"]),
        );
        if let Some(traces) = b.get("stats").and_then(|s| s.get("traces")).and_then(Json::as_arr)
        {
            for t in traces {
                let total = t.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let cur_worst = worst
                    .and_then(|(w, _)| w.get("total_ms"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(-1.0);
                if total > cur_worst {
                    worst = Some((t, baddr));
                }
            }
        }
    }
    match worst {
        Some((t, baddr)) => {
            let trace_id = t.get("trace_id").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let total = t.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let stages = t
                .get("stages")
                .and_then(Json::as_obj)
                .map(|o| {
                    o.iter()
                        .filter_map(|(k, v)| v.as_f64().map(|ms| format!("{k} {ms:.2}ms")))
                        .collect::<Vec<_>>()
                        .join(", ")
                })
                .unwrap_or_default();
            println!(
                "slowest: trace {trace_id:.0} on {baddr} — {total:.2}ms total ({stages})"
            );
        }
        None => println!("slowest: no traced requests in any backend ring yet"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(
    args: &Args,
    train: lcquant::data::Dataset,
    test: lcquant::data::Dataset,
    seed: u64,
) -> Result<Box<dyn lcquant::coordinator::Backend>> {
    let dir = lcquant::runtime::Engine::default_dir();
    if !lcquant::runtime::Engine::available(&dir) {
        return Err(anyhow!("--backend pjrt requires artifacts at {dir:?}"));
    }
    let engine = lcquant::runtime::Engine::open(&dir)?;
    Ok(Box::new(lcquant::runtime::PjrtBackend::new(
        engine,
        args.get_or("model", "lenet300"),
        train,
        Some(test),
        seed,
    )?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(
    _args: &Args,
    _train: lcquant::data::Dataset,
    _test: lcquant::data::Dataset,
    _seed: u64,
) -> Result<Box<dyn lcquant::coordinator::Backend>> {
    Err(anyhow!(
        "--backend pjrt requires building with `--features pjrt` (and real xla-rs bindings)"
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt_smoke(args: &Args) -> Result<()> {
    use lcquant::coordinator::Backend as _;
    use lcquant::runtime::{Engine, PjrtBackend};
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    if !Engine::available(&dir) {
        return Err(anyhow!("no artifacts at {dir:?}; run `make artifacts` first"));
    }
    let engine = Engine::open(&dir)?;
    println!(
        "manifest artifacts: {:?}",
        engine.manifest.artifacts.keys().collect::<Vec<_>>()
    );
    let mut data = SynthMnist::generate(600, 1);
    data.subtract_mean(None);
    let mut rng = Rng::new(2);
    let (train, test) = data.split(0.2, &mut rng);
    let mut backend = PjrtBackend::new(engine, "lenet300", train, Some(test), 3)?;
    let (loss, grads) = backend.next_loss_grads();
    println!(
        "pjrt grad step: loss={loss:.4}, {} layers",
        grads.layout().n_layers()
    );
    let (el, ee) = backend.eval_train();
    println!("pjrt eval: loss={el:.4} err={ee:.2}%");
    println!("pjrt-smoke OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt_smoke(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "pjrt-smoke requires building with `--features pjrt` (and real xla-rs bindings)"
    ))
}

fn main() {
    let args = Args::from_env();
    set_level(if args.has("verbose") { Level::Debug } else { Level::Info });
    let result = match args.command.as_str() {
        "experiment" => cmd_experiment(&args),
        "run" => cmd_run(&args),
        "pack" => cmd_pack(&args),
        "serve-smoke" => cmd_serve_smoke(&args),
        "serve-net" => cmd_serve_net(&args),
        "serve-fabric" => cmd_serve_fabric(&args),
        "client-smoke" => cmd_client_smoke(&args),
        "stats" => cmd_stats(&args),
        "top" => cmd_top(&args),
        "pjrt-smoke" => cmd_pjrt_smoke(&args),
        "list" => {
            println!("experiments: {:?}", experiments::ALL);
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
