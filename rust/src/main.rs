//! `lcquant` CLI — launcher for the LC quantization system.
//!
//! ```text
//! lcquant experiment <id|all> [--out results] [--scale quick|full] [--seed N]
//! lcquant run --config configs/lenet300_k2.json [--out results]
//! lcquant pjrt-smoke [--artifacts artifacts]
//! lcquant list
//! ```

use anyhow::{anyhow, Result};
use lcquant::config::RunConfig;
use lcquant::coordinator::{lc_quantize, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::experiments::{self, Scale};
use lcquant::nn::Mlp;
use lcquant::util::cli::Args;
use lcquant::util::log::{set_level, Level};
use lcquant::util::rng::Rng;

fn usage() -> ! {
    eprintln!(
        "usage:
  lcquant experiment <id|all> [--out DIR] [--scale quick|full] [--seed N]
      ids: {:?}
  lcquant run --config FILE [--out DIR]
  lcquant pjrt-smoke [--artifacts DIR]
  lcquant list",
        experiments::ALL
    );
    std::process::exit(2);
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let out = args.get_or("out", "results");
    let scale = Scale::from_str(args.get_or("scale", "quick"));
    let seed = args.get_u64("seed", 42);
    std::fs::create_dir_all(out)?;
    experiments::run(id, out, scale, seed)
}

fn cmd_run(args: &Args) -> Result<()> {
    use lcquant::coordinator::Backend;
    let cfg_path = args
        .get("config")
        .ok_or_else(|| anyhow!("run requires --config FILE"))?;
    let cfg = RunConfig::from_file(cfg_path)?;
    lcquant::info!("config '{}' loaded from {cfg_path}", cfg.name);

    let mut data = match cfg.data.kind.as_str() {
        "cifar_like" => lcquant::data::cifar_like::generate(cfg.data.n, cfg.seed),
        _ => SynthMnist::generate(cfg.data.n, cfg.seed),
    };
    data.subtract_mean(None);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let (train, test) = data.split(cfg.data.test_frac, &mut rng);

    // --backend pjrt runs the L step through the AOT artifact (requires
    // `make artifacts` and a net matching the artifact's architecture);
    // default is the pure-rust backend.
    let mut backend: Box<dyn Backend> = match args.get_or("backend", "native") {
        "pjrt" => {
            let dir = lcquant::runtime::Engine::default_dir();
            if !lcquant::runtime::Engine::available(&dir) {
                return Err(anyhow!("--backend pjrt requires artifacts at {dir:?}"));
            }
            let engine = lcquant::runtime::Engine::open(&dir)?;
            Box::new(lcquant::runtime::PjrtBackend::new(
                engine,
                args.get_or("model", "lenet300"),
                train,
                Some(test),
                cfg.seed,
            )?)
        }
        _ => {
            let net = Mlp::new(&cfg.net, cfg.seed);
            Box::new(NativeBackend::new(net, train, Some(test), cfg.train.batch, cfg.seed))
        }
    };
    let backend = backend.as_mut();

    // train the reference
    use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
    let mut opt = FlatNesterov::new(&backend.weights(), &backend.biases(), cfg.train.momentum);
    let chunk = 100usize;
    let mut step = 0;
    while step < cfg.train.ref_steps {
        let n = chunk.min(cfg.train.ref_steps - step);
        let lr = cfg.train.lr0 * cfg.train.lr_decay.powi((step / chunk) as i32);
        run_sgd(backend, &mut opt, n, lr, None);
        step += n;
    }
    let (rl, re) = backend.eval_train();
    lcquant::info!("reference: loss={rl:.5} err={re:.2}%");

    let res = lc_quantize(backend, &cfg.lc);
    println!(
        "LC done [{}]: quantized train loss {:.5}, train err {:.2}%, test err {:?}",
        cfg.lc.scheme.label(),
        res.train_loss,
        res.train_err,
        res.test_err
    );
    for (l, cb) in res.codebooks.iter().enumerate() {
        println!("  layer {} codebook: {:?}", l + 1, cb);
    }
    // persist history
    let out = args.get_or("out", "results");
    let mut hist = lcquant::metrics::History::new(&["iter", "mu", "lstep_loss", "feasibility"]);
    for r in &res.history {
        hist.push(vec![r.iter as f64, r.mu as f64, r.lstep_loss as f64, r.feasibility as f64]);
    }
    hist.save_csv(&std::path::Path::new(out).join(format!("{}_history.csv", cfg.name)))?;
    Ok(())
}

fn cmd_pjrt_smoke(args: &Args) -> Result<()> {
    use lcquant::coordinator::Backend as _;
    use lcquant::runtime::{Engine, PjrtBackend};
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    if !Engine::available(&dir) {
        return Err(anyhow!("no artifacts at {dir:?}; run `make artifacts` first"));
    }
    let engine = Engine::open(&dir)?;
    println!(
        "manifest artifacts: {:?}",
        engine.manifest.artifacts.keys().collect::<Vec<_>>()
    );
    let mut data = SynthMnist::generate(600, 1);
    data.subtract_mean(None);
    let mut rng = Rng::new(2);
    let (train, test) = data.split(0.2, &mut rng);
    let mut backend = PjrtBackend::new(engine, "lenet300", train, Some(test), 3)?;
    let (loss, grads) = backend.next_loss_grads();
    println!("pjrt grad step: loss={loss:.4}, {} layers", grads.dw.len());
    let (el, ee) = backend.eval_train();
    println!("pjrt eval: loss={el:.4} err={ee:.2}%");
    println!("pjrt-smoke OK");
    Ok(())
}

fn main() {
    let args = Args::from_env();
    set_level(if args.has("verbose") { Level::Debug } else { Level::Info });
    let result = match args.command.as_str() {
        "experiment" => cmd_experiment(&args),
        "run" => cmd_run(&args),
        "pjrt-smoke" => cmd_pjrt_smoke(&args),
        "list" => {
            println!("experiments: {:?}", experiments::ALL);
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
