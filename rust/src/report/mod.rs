//! ASCII table rendering for experiment output (the paper's tables are
//! regenerated as text tables on stdout + CSV on disk).

/// A simple aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "cell arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$} ", cell, w = widths[c]));
                line.push_str("| ");
            }
            line.pop();
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a float with fixed decimals; NaN-safe.
pub fn f(v: f64, decimals: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.decimals$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["K", "loss"]);
        t.row(vec!["2".into(), "0.123".into()]);
        t.row(vec!["64".into(), "1.5".into()]);
        let s = t.render();
        assert!(s.contains("|  K | "));
        assert!(s.contains("| 64 |"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(f(f64::NAN, 2), "-");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
