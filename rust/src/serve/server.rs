//! Micro-batching server: coalesces single-image requests into batches and
//! **pipelines** batch execution across a pool of executor threads.
//!
//! Single requests are latency-bound; the LUT engine (like any GEMM-shaped
//! kernel) is throughput-bound. The batcher thread takes the first queued
//! request, then keeps draining the channel until either `max_batch`
//! requests are in hand or `max_wait` has elapsed since the first one —
//! the classic latency/throughput knob. Coalesced batches are grouped per
//! model name (the registry serves a whole compression family) and handed
//! to [`ServerConfig::pipeline_depth`] executor threads, so:
//!
//! * the batcher is already coalescing the *next* batch while the previous
//!   one executes, and
//! * up to `pipeline_depth` batches run concurrently — their layer passes
//!   land as independent tasks on the multi-task worker pool
//!   ([`crate::linalg::pool`]), so layer N of request A overlaps layer M
//!   of request B instead of serializing behind one task slot.
//!
//! Each executor owns an
//! [`EngineScratch`](crate::serve::engine::EngineScratch) and forwards its
//! group's **pre-staged rows in place**
//! ([`LutEngine::forward_rows_into`](crate::serve::LutEngine::forward_rows_into)
//! reads each job's decoded buffer directly), so steady-state batch
//! execution performs no activation allocations *and no per-request input
//! copy* — the buffer a client (or the network plane's frame decoder)
//! hands to [`Client::submit`] is the buffer the engine gathers from.
//! Per-request latency is recorded (bounded sample window) for p50/p90/p99
//! reporting.
//!
//! Requests travel client → batcher over `mpsc`; coalesced groups travel
//! batcher → executors over a **lock-free bounded MPMC ring**
//! ([`crate::util::mpmc::RingQueue`]) — the executors used to share one
//! `Mutex<Receiver>`, which serialized every hand-off behind a lock held
//! across `recv`; the ring claims cells with a CAS and parks on a futex
//! only when empty. No async runtime anywhere (vendored crate set).

use super::engine::EngineScratch;
use super::registry::Registry;
use crate::util::mpmc::RingQueue;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Idle tick at which the batcher re-checks the shutdown flag (clients may
/// hold live `Sender` clones, so channel disconnection alone cannot signal
/// shutdown).
const SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Cap on retained latency samples: when full, the oldest half is dropped,
/// so memory stays bounded on a long-running server and percentiles lean
/// towards recent traffic. Totals are tracked separately in counters.
const STATS_CAP: usize = 65_536;

/// Batching and pipelining knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Hard cap on coalesced batch size.
    pub max_batch: usize,
    /// How long the first request in a batch may wait for company.
    pub max_wait: Duration,
    /// Executor threads running coalesced batches concurrently (clamped to
    /// ≥ 1). Depth 1 reproduces strictly serial execution (though batch
    /// N+1 still coalesces while batch N runs); deeper pipelines let
    /// concurrent batches overlap on the multi-task worker pool. Values
    /// past the pool width mostly add queueing, not throughput.
    pub pipeline_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            pipeline_depth: 2,
        }
    }
}

struct Job {
    model: String,
    input: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Result<Vec<f32>, String>>,
}

/// One per-model group of coalesced jobs, the unit handed to an executor.
struct BatchGroup {
    model: String,
    jobs: Vec<Job>,
}

#[derive(Default)]
struct Stats {
    /// Recent per-request latencies (bounded by [`STATS_CAP`]).
    latencies_ms: Vec<f32>,
    /// All-time counters.
    requests: usize,
    batches: usize,
    batched_requests: usize,
    errors: usize,
}

impl Stats {
    fn push_latency(&mut self, ms: f32) {
        if self.latencies_ms.len() >= STATS_CAP {
            self.latencies_ms.drain(..STATS_CAP / 2);
        }
        self.latencies_ms.push(ms);
        self.requests += 1;
    }
}

/// Point-in-time summary of server behaviour.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    /// Requests answered so far (success or error).
    pub requests: usize,
    /// Per-model batch groups executed.
    pub batches: usize,
    /// Requests answered with an error.
    pub errors: usize,
    /// Median request latency over the retained sample window, in ms.
    pub p50_ms: f32,
    /// 90th-percentile request latency, in ms.
    pub p90_ms: f32,
    /// 99th-percentile request latency, in ms.
    pub p99_ms: f32,
    /// Worst retained request latency, in ms.
    pub max_ms: f32,
    /// Mean requests per executed batch group.
    pub mean_batch: f64,
}

/// Cloneable request handle; blocking [`Client::infer`] calls can be made
/// from any number of threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Job>,
}

impl Client {
    /// Send one input and block for its logits.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(model, input, reply_tx)?;
        reply_rx.recv().map_err(|_| "server dropped request".to_string())?
    }

    /// Submit one **pre-staged** input row without blocking for the reply;
    /// the logits (or an error string) arrive on `reply`.
    ///
    /// The row `Vec` is handed to the engine as-is: the executors gather
    /// straight from it via
    /// [`LutEngine::forward_rows_into`](crate::serve::LutEngine::forward_rows_into),
    /// so a caller that deserializes wire floats directly into `input`
    /// (the network plane's frame decoder does) pays **zero further
    /// copies** between the socket and the batched forward pass. A reply
    /// channel may be reused across sequential submissions, but note the
    /// tradeoff: while the caller holds its own `Sender` clone the
    /// channel can never disconnect, so a job dropped without an answer
    /// blocks `recv` instead of erroring — callers that must stay live
    /// through server faults (the network plane) use a fresh channel per
    /// request.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
        reply: Sender<Result<Vec<f32>, String>>,
    ) -> Result<(), String> {
        self.tx
            .send(Job {
                model: model.to_string(),
                input,
                enqueued: Instant::now(),
                reply,
            })
            .map_err(|_| "server stopped".to_string())
    }
}

/// The batcher thread, its executor pool, and their stats. Stops (draining
/// nothing further) when dropped or [`MicroBatchServer::stop`] is called.
pub struct MicroBatchServer {
    tx: Option<Sender<Job>>,
    batcher: Option<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<Stats>>,
    shutdown: Arc<AtomicBool>,
}

impl MicroBatchServer {
    /// Spawn the batcher and `cfg.pipeline_depth` executors over a shared
    /// registry.
    pub fn start(registry: Arc<Registry>, cfg: ServerConfig) -> MicroBatchServer {
        let (tx, rx) = mpsc::channel::<Job>();
        let depth = cfg.pipeline_depth.max(1);
        // a few groups of slack beyond the executor count: the batcher can
        // stay ahead without the ring ever becoming an unbounded buffer
        let queue = Arc::new(RingQueue::<BatchGroup>::new((depth * 2).max(8)));
        let stats = Arc::new(Mutex::new(Stats::default()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let executors = (0..depth)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let registry = Arc::clone(&registry);
                let stats = Arc::clone(&stats);
                std::thread::Builder::new()
                    .name(format!("lcq-serve-exec-{i}"))
                    .spawn(move || executor_loop(queue, registry, stats))
                    .expect("spawn serve executor")
            })
            .collect();
        let shutdown_w = Arc::clone(&shutdown);
        let batcher = std::thread::Builder::new()
            .name("lcq-serve-batch".to_string())
            .spawn(move || batcher_loop(rx, queue, cfg, shutdown_w))
            .expect("spawn serve batcher");
        MicroBatchServer {
            tx: Some(tx),
            batcher: Some(batcher),
            executors,
            stats,
            shutdown,
        }
    }

    /// A request handle (cloneable, thread-safe).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.as_ref().expect("server running").clone() }
    }

    /// Latency/batching summary so far (percentiles over the retained
    /// sample window, counters over the server's lifetime).
    pub fn stats(&self) -> StatsSnapshot {
        // sort once outside the lock so the executors are not stalled
        let (mut lat, requests, batches, batched_requests, errors) = {
            let s = self.stats.lock().unwrap();
            (s.latencies_ms.clone(), s.requests, s.batches, s.batched_requests, s.errors)
        };
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        StatsSnapshot {
            requests,
            batches,
            errors,
            p50_ms: crate::metrics::percentile_sorted(&lat, 50.0),
            p90_ms: crate::metrics::percentile_sorted(&lat, 90.0),
            p99_ms: crate::metrics::percentile_sorted(&lat, 99.0),
            max_ms: lat.last().copied().unwrap_or(0.0),
            mean_batch: if batches == 0 {
                0.0
            } else {
                batched_requests as f64 / batches as f64
            },
        }
    }

    /// Stop accepting requests and join the batcher and executors
    /// (already-coalesced requests are answered first; later ones get a
    /// clean error).
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        drop(self.tx.take());
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        // the batcher closed the group ring on exit; executors drain what
        // it already queued, then see the closed+empty ring and exit
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for MicroBatchServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn batcher_loop(
    rx: Receiver<Job>,
    queue: Arc<RingQueue<BatchGroup>>,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) {
    batcher_run(&rx, &queue, &cfg, &shutdown);
    // no more groups will ever be produced: executors drain what is
    // already queued, then exit on the closed+empty ring
    queue.close();
}

fn batcher_run(
    rx: &Receiver<Job>,
    queue: &RingQueue<BatchGroup>,
    cfg: &ServerConfig,
    shutdown: &AtomicBool,
) {
    let max_batch = cfg.max_batch.max(1);
    loop {
        // wait for the head-of-batch request, polling the shutdown flag
        let first = match rx.recv_timeout(SHUTDOWN_POLL) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return, // all senders gone
        };
        let deadline = first.enqueued + cfg.max_wait;
        let mut jobs = vec![first];
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // stable grouping by model name (preserves request order per
        // model); each group is one executor work unit
        let mut groups: Vec<BatchGroup> = Vec::new();
        for job in jobs {
            match groups.iter_mut().find(|g| g.model == job.model) {
                Some(g) => g.jobs.push(job),
                None => groups.push(BatchGroup { model: job.model.clone(), jobs: vec![job] }),
            }
        }
        for group in groups {
            // blocking MPMC push: backpressure when all executors are busy
            // and the ring is full. Only this thread closes the queue, so
            // a failed push means a shutdown race lost — fail cleanly.
            if let Err(group) = queue.push(group) {
                for job in &group.jobs {
                    let _ = job.reply.send(Err("server stopped".to_string()));
                }
                return;
            }
        }
    }
}

/// One pipeline executor: pull per-model groups off the lock-free MPMC
/// ring and run them. Cell claims are a CAS (no lock is ever held across
/// the hand-off), so up to `pipeline_depth` groups execute concurrently
/// while the batcher keeps coalescing.
fn executor_loop(
    queue: Arc<RingQueue<BatchGroup>>,
    registry: Arc<Registry>,
    stats: Arc<Mutex<Stats>>,
) {
    let mut scratch = EngineScratch::new();
    let mut latencies = Vec::new();
    // pop returns None only once the batcher closed the ring and every
    // queued group has been drained
    while let Some(group) = queue.pop() {
        run_group(&registry, group, &stats, &mut scratch, &mut latencies);
    }
}

/// Forward one per-model group in a single batched engine call and answer
/// every request. `scratch` and `latencies` are the executor's reusable
/// buffers.
fn run_group(
    registry: &Registry,
    group: BatchGroup,
    stats: &Arc<Mutex<Stats>>,
    scratch: &mut EngineScratch,
    latencies: &mut Vec<f32>,
) {
    let BatchGroup { model, jobs } = group;
    let outcome: Result<&crate::linalg::Mat, String> = match registry.get(&model) {
        None => Err(format!("model '{model}' not registered")),
        Some(loaded) => {
            let in_dim = loaded.engine.in_dim();
            match jobs.iter().find(|j| j.input.len() != in_dim) {
                Some(bad) => Err(format!(
                    "model '{model}' expects {in_dim} features, got {}",
                    bad.input.len()
                )),
                // pre-staged rows: the engine gathers straight from each
                // job's decoded buffer — no copy into a batch matrix
                None => Ok(loaded
                    .engine
                    .forward_rows_into(jobs.len(), |r| jobs[r].input.as_slice(), scratch)),
            }
        }
    };
    // Answer every request and measure latencies *outside* the stats lock:
    // the per-job row clones and channel sends are O(batch), and holding
    // the shared mutex across them would serialize the pipeline executors
    // at the end of every batch.
    latencies.clear();
    let errors = match outcome {
        Ok(y) => {
            for (r, job) in jobs.iter().enumerate() {
                latencies.push(job.enqueued.elapsed().as_secs_f32() * 1e3);
                let _ = job.reply.send(Ok(y.row(r).to_vec()));
            }
            0
        }
        Err(e) => {
            for job in &jobs {
                latencies.push(job.enqueued.elapsed().as_secs_f32() * 1e3);
                let _ = job.reply.send(Err(e.clone()));
            }
            jobs.len()
        }
    };
    let mut s = stats.lock().unwrap();
    s.batches += 1;
    s.batched_requests += jobs.len();
    s.errors += errors;
    for &ms in latencies.iter() {
        s.push_latency(ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{Activation, MlpSpec};
    use crate::quant::{LayerQuantizer, Scheme};
    use crate::serve::packed::PackedModel;
    use crate::util::rng::Rng;

    fn toy_registry() -> (Arc<Registry>, PackedModel) {
        let spec = MlpSpec {
            sizes: vec![8, 6, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(4);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let out = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, l as u64)
                .compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push(vec![0.05f32; spec.sizes[l + 1]]);
        }
        let packed = PackedModel::from_parts(
            "toy",
            &spec,
            &Scheme::AdaptiveCodebook { k: 4 },
            &codebooks,
            &assignments,
            &biases,
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.insert(packed.clone()).unwrap();
        (Arc::new(reg), packed)
    }

    #[test]
    fn serves_correct_logits() {
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                pipeline_depth: 1,
            },
        );
        let client = server.client();
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let input: Vec<f32> = (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
            let got = client.infer("toy", input.clone()).unwrap();
            let mut x = Mat::zeros(1, 8);
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward(&x);
            assert_eq!(got, want.row(0).to_vec());
        }
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.errors, 0);
        assert!(stats.p50_ms >= 0.0 && stats.p99_ms >= stats.p50_ms);
    }

    #[test]
    fn concurrent_requests_coalesce_into_batches() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(100),
                pipeline_depth: 2,
            },
        );
        let client = server.client();
        let n_threads = 12;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let c = client.clone();
                s.spawn(move || {
                    let input = vec![0.1f32 * t as f32; 8];
                    c.infer("toy", input).unwrap()
                });
            }
        });
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, n_threads);
        // with a 100ms window, a 12-thread burst must coalesce at least
        // once: fewer batches than requests ⇔ some batch had size ≥ 2
        assert!(stats.batches < stats.requests, "no coalescing: {stats:?}");
        assert!(stats.mean_batch > 1.0, "{stats:?}");
    }

    #[test]
    fn pipelined_burst_is_answered_correctly_at_depth() {
        // small max_batch + several executors: many groups in flight at
        // once; every reply must still match the direct engine forward
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                pipeline_depth: 3,
            },
        );
        let client = server.client();
        let n_threads = 16usize;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let c = client.clone();
                let engine = &engine;
                s.spawn(move || {
                    let mut rng = Rng::new(900 + t as u64);
                    for _ in 0..4 {
                        let input: Vec<f32> =
                            (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
                        let got = c.infer("toy", input.clone()).unwrap();
                        let mut x = Mat::zeros(1, 8);
                        x.row_mut(0).copy_from_slice(&input);
                        let want = engine.forward(&x);
                        assert_eq!(got, want.row(0).to_vec(), "client {t}");
                    }
                });
            }
        });
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, n_threads * 4);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn submit_with_reusable_reply_channel() {
        // the network plane's usage pattern: one reply channel per
        // connection, reused across sequential submissions
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut rng = Rng::new(55);
        for _ in 0..6 {
            let input: Vec<f32> = (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
            client.submit("toy", input.clone(), reply_tx.clone()).unwrap();
            let got = reply_rx.recv().unwrap().unwrap();
            let mut x = Mat::zeros(1, 8);
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward(&x);
            assert_eq!(got, want.row(0).to_vec());
        }
        server.stop();
        assert_eq!(server.stats().requests, 6);
    }

    #[test]
    fn unknown_model_and_bad_arity_are_reported() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let err = client.infer("ghost", vec![0.0; 8]).unwrap_err();
        assert!(err.contains("not registered"), "{err}");
        let err = client.infer("toy", vec![0.0; 3]).unwrap_err();
        assert!(err.contains("features"), "{err}");
        server.stop();
        assert_eq!(server.stats().errors, 2);
        // after stop, requests fail cleanly instead of hanging
        assert!(client.infer("toy", vec![0.0; 8]).is_err());
    }
}
