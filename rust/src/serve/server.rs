//! Micro-batching server: coalesces single-image requests into batches and
//! **pipelines** batch execution across a pool of executor threads.
//!
//! Single requests are latency-bound; the LUT engine (like any GEMM-shaped
//! kernel) is throughput-bound. The batcher thread takes the first queued
//! request, then keeps draining the channel until either `max_batch`
//! requests are in hand or `max_wait` has elapsed since the first one —
//! the classic latency/throughput knob. Coalesced batches are grouped per
//! model name (the registry serves a whole compression family) and handed
//! to [`ServerConfig::pipeline_depth`] executor threads, so:
//!
//! * the batcher is already coalescing the *next* batch while the previous
//!   one executes, and
//! * up to `pipeline_depth` batches run concurrently — their layer passes
//!   land as independent tasks on the multi-task worker pool
//!   ([`crate::linalg::pool`]), so layer N of request A overlaps layer M
//!   of request B instead of serializing behind one task slot.
//!
//! Each executor owns an
//! [`EngineScratch`](crate::serve::engine::EngineScratch) and forwards its
//! group's **pre-staged rows in place**
//! ([`LutEngine::forward_rows_into`](crate::serve::LutEngine::forward_rows_into)
//! reads each job's decoded buffer directly), so steady-state batch
//! execution performs no activation allocations *and no per-request input
//! copy* — the buffer a client (or the network plane's frame decoder)
//! hands to [`Client::submit`] is the buffer the engine gathers from.
//!
//! Per-request observability rides the reply path: every answer is a
//! [`JobOutcome`] carrying queue-wait / batch-assembly / compute span
//! times alongside the logits, and the server records every request into
//! a lock-free [`ServeStats`] (atomic counters + [`obs`] log₂ latency
//! histograms — no mutex, no retained sample `Vec`, no sort on read) that
//! also mirrors into the process-wide [`obs`] registry.
//!
//! Requests travel client → batcher over `mpsc`; coalesced groups travel
//! batcher → executors over a **lock-free bounded MPMC ring**
//! ([`crate::util::mpmc::RingQueue`]) — the executors used to share one
//! `Mutex<Receiver>`, which serialized every hand-off behind a lock held
//! across `recv`; the ring claims cells with a CAS and parks on a futex
//! only when empty. No async runtime anywhere (vendored crate set).

use super::engine::EngineScratch;
use super::registry::Registry;
use crate::obs::{self, CounterId, Histogram, HistId};
use crate::util::json::Json;
use crate::util::mpmc::RingQueue;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Idle tick at which the batcher re-checks the shutdown flag (clients may
/// hold live `Sender` clones, so channel disconnection alone cannot signal
/// shutdown).
const SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Batching and pipelining knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Hard cap on coalesced batch size.
    pub max_batch: usize,
    /// How long the first request in a batch may wait for company.
    pub max_wait: Duration,
    /// Executor threads running coalesced batches concurrently (clamped to
    /// ≥ 1). Depth 1 reproduces strictly serial execution (though batch
    /// N+1 still coalesces while batch N runs); deeper pipelines let
    /// concurrent batches overlap on the multi-task worker pool. Values
    /// past the pool width mostly add queueing, not throughput.
    pub pipeline_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            pipeline_depth: 2,
        }
    }
}

/// What comes back on a reply channel: the result plus the request's span
/// times through the batching pipeline, so the caller (e.g. the network
/// plane's trace recorder) sees where the latency went without any side
/// channel.
#[derive(Debug)]
pub struct JobOutcome {
    /// The logits, or an error string.
    pub result: Result<Vec<f32>, String>,
    /// Time spent waiting in the batcher queue (enqueue → batch cut), ns.
    pub queue_ns: u64,
    /// Batch assembly time (batch cut → executor pickup), ns.
    pub assembly_ns: u64,
    /// Batched forward-pass wall time, ns.
    pub compute_ns: u64,
    /// Size of the batch this request rode in.
    pub batch_size: u32,
}

/// How a job's [`JobOutcome`] travels back to its submitter: a channel
/// send (blocking in-process clients) or a one-shot callback (the
/// event-driven network plane, whose net threads must never block on a
/// `recv`). Delivery consumes the route either way, so a job is answered
/// exactly once.
enum Reply {
    Chan(Sender<JobOutcome>),
    Cb(Box<dyn FnOnce(JobOutcome) + Send>),
}

impl Reply {
    fn deliver(self, outcome: JobOutcome) {
        match self {
            // A gone receiver just means the submitter stopped waiting.
            Reply::Chan(tx) => drop(tx.send(outcome)),
            Reply::Cb(cb) => cb(outcome),
        }
    }
}

struct Job {
    model: String,
    input: Vec<f32>,
    enqueued: Instant,
    reply: Reply,
}

/// One per-model group of coalesced jobs, the unit handed to an executor.
struct BatchGroup {
    model: String,
    jobs: Vec<Job>,
    /// When the batcher cut this group (queue wait ends, assembly begins).
    assembled: Instant,
}

/// Lock-free request statistics: all-time counters plus log₂ latency
/// histograms, every field a relaxed atomic. The recording path (one
/// `fetch_add` per counter/bucket) is zero-alloc and lock-free — asserted
/// by the counting-allocator test in `rust/tests/obs.rs`. Shared between
/// the executors, [`MicroBatchServer::stats`], and (via
/// [`MicroBatchServer::stats_handle`]) the network plane's `Stats` frame —
/// so a snapshot is valid at every lifecycle point, including after the
/// server stopped.
#[derive(Default)]
pub struct ServeStats {
    requests: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    errors: AtomicU64,
    /// End-to-end request latency (enqueue → reply).
    latency: Histogram,
    queue_wait: Histogram,
    assembly: Histogram,
    compute: Histogram,
}

impl ServeStats {
    /// Record one executed group: `ns` spans apply batch-wide, the latency
    /// histogram gets one sample per job.
    fn record_group(
        &self,
        batch: usize,
        errors: usize,
        queue_ns: &[u64],
        latency_ns: &[u64],
        assembly_ns: u64,
        compute_ns: u64,
    ) {
        self.requests.fetch_add(batch as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(batch as u64, Ordering::Relaxed);
        self.errors.fetch_add(errors as u64, Ordering::Relaxed);
        self.assembly.record_ns(assembly_ns);
        self.compute.record_ns(compute_ns);
        for (&q, &l) in queue_ns.iter().zip(latency_ns) {
            self.queue_wait.record_ns(q);
            self.latency.record_ns(l);
        }
        if obs::enabled() {
            obs::counter(CounterId::ServeRequests).add(batch as u64);
            obs::counter(CounterId::ServeBatches).inc();
            obs::counter(CounterId::ServeBatchedRequests).add(batch as u64);
            obs::counter(CounterId::ServeErrors).add(errors as u64);
            obs::hist(HistId::ServeAssembly).record_ns(assembly_ns);
            obs::hist(HistId::ServeCompute).record_ns(compute_ns);
            for (&q, &l) in queue_ns.iter().zip(latency_ns) {
                obs::hist(HistId::ServeQueueWait).record_ns(q);
                obs::hist(HistId::ServeLatency).record_ns(l);
            }
        }
    }

    /// Requests answered so far.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Point-in-time summary (histogram percentiles, exact counters).
    pub fn snapshot(&self) -> StatsSnapshot {
        let lat = self.latency.snapshot();
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_requests.load(Ordering::Relaxed);
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed) as usize,
            batches: batches as usize,
            errors: self.errors.load(Ordering::Relaxed) as usize,
            p50_ms: lat.percentile_ms(50.0),
            p90_ms: lat.percentile_ms(90.0),
            p99_ms: lat.percentile_ms(99.0),
            max_ms: lat.max_ms(),
            mean_batch: if batches == 0 { 0.0 } else { batched as f64 / batches as f64 },
        }
    }

    /// Full JSON rendering for the wire `Stats` snapshot: counters plus
    /// every span histogram.
    pub fn to_json(&self) -> Json {
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_requests.load(Ordering::Relaxed);
        Json::obj(vec![
            ("requests", Json::from(self.requests.load(Ordering::Relaxed) as usize)),
            ("batches", Json::from(batches as usize)),
            ("batched_requests", Json::from(batched as usize)),
            ("errors", Json::from(self.errors.load(Ordering::Relaxed) as usize)),
            (
                "mean_batch",
                Json::from(if batches == 0 { 0.0 } else { batched as f64 / batches as f64 }),
            ),
            ("latency", self.latency.snapshot().to_json()),
            ("queue_wait", self.queue_wait.snapshot().to_json()),
            ("assembly", self.assembly.snapshot().to_json()),
            ("compute", self.compute.snapshot().to_json()),
        ])
    }
}

/// Point-in-time summary of server behaviour.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    /// Requests answered so far (success or error).
    pub requests: usize,
    /// Per-model batch groups executed.
    pub batches: usize,
    /// Requests answered with an error.
    pub errors: usize,
    /// Median request latency (log₂-histogram percentile), in ms.
    pub p50_ms: f32,
    /// 90th-percentile request latency, in ms.
    pub p90_ms: f32,
    /// 99th-percentile request latency, in ms.
    pub p99_ms: f32,
    /// Worst recorded request latency (bucket upper edge), in ms.
    pub max_ms: f32,
    /// Mean requests per executed batch group.
    pub mean_batch: f64,
}

/// Cloneable request handle; blocking [`Client::infer`] calls can be made
/// from any number of threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Job>,
}

impl Client {
    /// Send one input and block for its logits.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(model, input, reply_tx)?;
        reply_rx.recv().map_err(|_| "server dropped request".to_string())?.result
    }

    /// Submit one **pre-staged** input row without blocking for the reply;
    /// a [`JobOutcome`] (logits or error, plus pipeline span times)
    /// arrives on `reply`.
    ///
    /// The row `Vec` is handed to the engine as-is: the executors gather
    /// straight from it via
    /// [`LutEngine::forward_rows_into`](crate::serve::LutEngine::forward_rows_into),
    /// so a caller that deserializes wire floats directly into `input`
    /// (the network plane's frame decoder does) pays **zero further
    /// copies** between the socket and the batched forward pass. A reply
    /// channel may be reused across sequential submissions, but note the
    /// tradeoff: while the caller holds its own `Sender` clone the
    /// channel can never disconnect, so a job dropped without an answer
    /// blocks `recv` instead of erroring — callers that must stay live
    /// through server faults (the network plane) use a fresh channel per
    /// request.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
        reply: Sender<JobOutcome>,
    ) -> Result<(), String> {
        self.push(model, input, Reply::Chan(reply))
    }

    /// Like [`Client::submit`], but the [`JobOutcome`] is delivered by
    /// invoking `on_done` from whichever executor thread finished the
    /// batch. This is the event-driven network plane's route: its net
    /// threads park in a readiness wait, not a channel `recv`, so the
    /// callback posts a completion and wakes the poller instead. The
    /// callback runs exactly once (including on the shutdown path, where
    /// it carries the batcher's error) unless the server's queue is
    /// already gone, in which case this returns `Err` and `on_done` is
    /// dropped unrun.
    pub fn submit_with(
        &self,
        model: &str,
        input: Vec<f32>,
        on_done: impl FnOnce(JobOutcome) + Send + 'static,
    ) -> Result<(), String> {
        self.push(model, input, Reply::Cb(Box::new(on_done)))
    }

    fn push(&self, model: &str, input: Vec<f32>, reply: Reply) -> Result<(), String> {
        self.tx
            .send(Job {
                model: model.to_string(),
                input,
                enqueued: Instant::now(),
                reply,
            })
            .map_err(|_| "server stopped".to_string())
    }
}

/// The batcher thread, its executor pool, and their stats. Stops (draining
/// nothing further) when dropped or [`MicroBatchServer::stop`] is called.
pub struct MicroBatchServer {
    tx: Option<Sender<Job>>,
    batcher: Option<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
}

impl MicroBatchServer {
    /// Spawn the batcher and `cfg.pipeline_depth` executors over a shared
    /// registry.
    pub fn start(registry: Arc<Registry>, cfg: ServerConfig) -> MicroBatchServer {
        let (tx, rx) = mpsc::channel::<Job>();
        let depth = cfg.pipeline_depth.max(1);
        // a few groups of slack beyond the executor count: the batcher can
        // stay ahead without the ring ever becoming an unbounded buffer
        let queue = Arc::new(RingQueue::<BatchGroup>::new((depth * 2).max(8)));
        let stats = Arc::new(ServeStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let executors = (0..depth)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let registry = Arc::clone(&registry);
                let stats = Arc::clone(&stats);
                std::thread::Builder::new()
                    .name(format!("lcq-serve-exec-{i}"))
                    .spawn(move || executor_loop(queue, registry, stats))
                    .expect("spawn serve executor")
            })
            .collect();
        let shutdown_w = Arc::clone(&shutdown);
        let batcher = std::thread::Builder::new()
            .name("lcq-serve-batch".to_string())
            .spawn(move || batcher_loop(rx, queue, cfg, shutdown_w))
            .expect("spawn serve batcher");
        MicroBatchServer {
            tx: Some(tx),
            batcher: Some(batcher),
            executors,
            stats,
            shutdown,
        }
    }

    /// A request handle (cloneable, thread-safe).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.as_ref().expect("server running").clone() }
    }

    /// Latency/batching summary so far (histogram percentiles, lifetime
    /// counters). Lock-free: never stalls the executors.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// A shared handle to the live stats. The handle stays valid after
    /// [`MicroBatchServer::stop`] — and even after the server is dropped —
    /// so exposition paths (the network plane's `Stats` frame) can snapshot
    /// at any lifecycle point without racing the shutdown sequence.
    pub fn stats_handle(&self) -> Arc<ServeStats> {
        Arc::clone(&self.stats)
    }

    /// Stop accepting requests and join the batcher and executors
    /// (already-coalesced requests are answered first; later ones get a
    /// clean error).
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        drop(self.tx.take());
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        // the batcher closed the group ring on exit; executors drain what
        // it already queued, then see the closed+empty ring and exit
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for MicroBatchServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn batcher_loop(
    rx: Receiver<Job>,
    queue: Arc<RingQueue<BatchGroup>>,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) {
    batcher_run(&rx, &queue, &cfg, &shutdown);
    // no more groups will ever be produced: executors drain what is
    // already queued, then exit on the closed+empty ring
    queue.close();
}

/// Answer every job in a group with the same error (shutdown path).
/// Consumes the group: reply delivery is one-shot.
fn fail_group(group: BatchGroup, msg: &str) {
    let batch = group.jobs.len() as u32;
    for job in group.jobs {
        job.reply.deliver(JobOutcome {
            result: Err(msg.to_string()),
            queue_ns: 0,
            assembly_ns: 0,
            compute_ns: 0,
            batch_size: batch,
        });
    }
}

fn batcher_run(
    rx: &Receiver<Job>,
    queue: &RingQueue<BatchGroup>,
    cfg: &ServerConfig,
    shutdown: &AtomicBool,
) {
    let max_batch = cfg.max_batch.max(1);
    loop {
        // wait for the head-of-batch request, polling the shutdown flag
        let first = match rx.recv_timeout(SHUTDOWN_POLL) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return, // all senders gone
        };
        let deadline = first.enqueued + cfg.max_wait;
        let mut jobs = vec![first];
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // stable grouping by model name (preserves request order per
        // model); each group is one executor work unit. The cut instant
        // marks the end of every member's queue wait.
        let assembled = Instant::now();
        let mut groups: Vec<BatchGroup> = Vec::new();
        for job in jobs {
            match groups.iter_mut().find(|g| g.model == job.model) {
                Some(g) => g.jobs.push(job),
                None => groups.push(BatchGroup {
                    model: job.model.clone(),
                    jobs: vec![job],
                    assembled,
                }),
            }
        }
        for group in groups {
            // blocking MPMC push: backpressure when all executors are busy
            // and the ring is full. Only this thread closes the queue, so
            // a failed push means a shutdown race lost — fail cleanly.
            if let Err(group) = queue.push(group) {
                fail_group(group, "server stopped");
                return;
            }
        }
    }
}

/// One pipeline executor: pull per-model groups off the lock-free MPMC
/// ring and run them. Cell claims are a CAS (no lock is ever held across
/// the hand-off), so up to `pipeline_depth` groups execute concurrently
/// while the batcher keeps coalescing.
fn executor_loop(
    queue: Arc<RingQueue<BatchGroup>>,
    registry: Arc<Registry>,
    stats: Arc<ServeStats>,
) {
    let mut scratch = EngineScratch::new();
    let mut queue_ns = Vec::new();
    let mut latency_ns = Vec::new();
    // pop returns None only once the batcher closed the ring and every
    // queued group has been drained
    while let Some(group) = queue.pop() {
        run_group(&registry, group, &stats, &mut scratch, &mut queue_ns, &mut latency_ns);
    }
}

#[inline]
fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Forward one per-model group in a single batched engine call and answer
/// every request. `scratch` and the span buffers are the executor's
/// reusable scratch.
fn run_group(
    registry: &Registry,
    group: BatchGroup,
    stats: &Arc<ServeStats>,
    scratch: &mut EngineScratch,
    queue_ns: &mut Vec<u64>,
    latency_ns: &mut Vec<u64>,
) {
    let BatchGroup { model, jobs, assembled } = group;
    let picked = Instant::now();
    let assembly_ns = dur_ns(picked.saturating_duration_since(assembled));
    let outcome: Result<&crate::linalg::Mat, String> = match registry.get(&model) {
        None => Err(format!("model '{model}' not registered")),
        Some(loaded) => {
            let in_dim = loaded.engine.in_dim();
            match jobs.iter().find(|j| j.input.len() != in_dim) {
                Some(bad) => Err(format!(
                    "model '{model}' expects {in_dim} features, got {}",
                    bad.input.len()
                )),
                // pre-staged rows: the engine reads straight from each
                // job's decoded buffer — no copy into a batch matrix. An
                // Err here means a lazily verified plane section failed
                // its checksum (corrupt model data), reported per request.
                None => loaded
                    .engine
                    .forward_rows_into(jobs.len(), |r| jobs[r].input.as_slice(), scratch)
                    .map_err(|e| format!("model '{model}': {e:#}")),
            }
        }
    };
    let compute_ns = dur_ns(picked.elapsed());
    // Answer every request; span times are reused from the executor's
    // scratch buffers, and the stats path is all relaxed atomics, so the
    // pipeline executors never serialize behind a lock at batch end.
    queue_ns.clear();
    latency_ns.clear();
    let batch = jobs.len();
    let errors = match outcome {
        Ok(y) => {
            for (r, job) in jobs.into_iter().enumerate() {
                let q = dur_ns(assembled.saturating_duration_since(job.enqueued));
                queue_ns.push(q);
                latency_ns.push(dur_ns(job.enqueued.elapsed()));
                job.reply.deliver(JobOutcome {
                    result: Ok(y.row(r).to_vec()),
                    queue_ns: q,
                    assembly_ns,
                    compute_ns,
                    batch_size: batch as u32,
                });
            }
            0
        }
        Err(e) => {
            for job in jobs {
                let q = dur_ns(assembled.saturating_duration_since(job.enqueued));
                queue_ns.push(q);
                latency_ns.push(dur_ns(job.enqueued.elapsed()));
                job.reply.deliver(JobOutcome {
                    result: Err(e.clone()),
                    queue_ns: q,
                    assembly_ns,
                    compute_ns,
                    batch_size: batch as u32,
                });
            }
            batch
        }
    };
    stats.record_group(batch, errors, queue_ns, latency_ns, assembly_ns, compute_ns);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{Activation, MlpSpec};
    use crate::quant::{LayerQuantizer, Scheme};
    use crate::serve::packed::PackedModel;
    use crate::util::rng::Rng;

    fn toy_registry() -> (Arc<Registry>, PackedModel) {
        let spec = MlpSpec {
            sizes: vec![8, 6, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(4);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let out = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, l as u64)
                .compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push(vec![0.05f32; spec.sizes[l + 1]]);
        }
        let packed = PackedModel::from_parts(
            "toy",
            &spec,
            &Scheme::AdaptiveCodebook { k: 4 },
            &codebooks,
            &assignments,
            &biases,
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.insert(packed.clone()).unwrap();
        (Arc::new(reg), packed)
    }

    #[test]
    fn serves_correct_logits() {
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                pipeline_depth: 1,
            },
        );
        let client = server.client();
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let input: Vec<f32> = (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
            let got = client.infer("toy", input.clone()).unwrap();
            let mut x = Mat::zeros(1, 8);
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward(&x).unwrap();
            assert_eq!(got, want.row(0).to_vec());
        }
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.errors, 0);
        assert!(stats.p50_ms >= 0.0 && stats.p99_ms >= stats.p50_ms);
    }

    #[test]
    fn concurrent_requests_coalesce_into_batches() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(100),
                pipeline_depth: 2,
            },
        );
        let client = server.client();
        let n_threads = 12;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let c = client.clone();
                s.spawn(move || {
                    let input = vec![0.1f32 * t as f32; 8];
                    c.infer("toy", input).unwrap()
                });
            }
        });
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, n_threads);
        // with a 100ms window, a 12-thread burst must coalesce at least
        // once: fewer batches than requests ⇔ some batch had size ≥ 2
        assert!(stats.batches < stats.requests, "no coalescing: {stats:?}");
        assert!(stats.mean_batch > 1.0, "{stats:?}");
    }

    #[test]
    fn pipelined_burst_is_answered_correctly_at_depth() {
        // small max_batch + several executors: many groups in flight at
        // once; every reply must still match the direct engine forward
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(
            reg,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                pipeline_depth: 3,
            },
        );
        let client = server.client();
        let n_threads = 16usize;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let c = client.clone();
                let engine = &engine;
                s.spawn(move || {
                    let mut rng = Rng::new(900 + t as u64);
                    for _ in 0..4 {
                        let input: Vec<f32> =
                            (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
                        let got = c.infer("toy", input.clone()).unwrap();
                        let mut x = Mat::zeros(1, 8);
                        x.row_mut(0).copy_from_slice(&input);
                        let want = engine.forward(&x).unwrap();
                        assert_eq!(got, want.row(0).to_vec(), "client {t}");
                    }
                });
            }
        });
        server.stop();
        let stats = server.stats();
        assert_eq!(stats.requests, n_threads * 4);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn submit_with_reusable_reply_channel() {
        // the network plane's usage pattern: one reply channel per
        // connection, reused across sequential submissions
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut rng = Rng::new(55);
        for _ in 0..6 {
            let input: Vec<f32> = (0..8).map(|_| rng.normal(0.0, 1.0)).collect();
            client.submit("toy", input.clone(), reply_tx.clone()).unwrap();
            let outcome = reply_rx.recv().unwrap();
            assert!(outcome.batch_size >= 1);
            let got = outcome.result.unwrap();
            let mut x = Mat::zeros(1, 8);
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward(&x).unwrap();
            assert_eq!(got, want.row(0).to_vec());
        }
        server.stop();
        assert_eq!(server.stats().requests, 6);
    }

    #[test]
    fn submit_with_callback_delivers_once_and_rejects_after_stop() {
        // the event plane's usage pattern: a one-shot callback instead of
        // a reply channel, answered from an executor thread
        let (reg, packed) = toy_registry();
        let engine = crate::serve::LutEngine::new(&packed).unwrap();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let (tx, rx) = mpsc::channel();
        let input = vec![0.25f32; 8];
        client
            .submit_with("toy", input.clone(), move |o| {
                let _ = tx.send(o);
            })
            .unwrap();
        let outcome = rx.recv().unwrap();
        let got = outcome.result.unwrap();
        let mut x = Mat::zeros(1, 8);
        x.row_mut(0).copy_from_slice(&input);
        assert_eq!(got, engine.forward(&x).unwrap().row(0).to_vec());
        server.stop();
        // after stop the queue is gone: submission fails loudly and the
        // callback is dropped unrun
        assert!(client.submit_with("toy", vec![0.0; 8], |_| {}).is_err());
        assert_eq!(server.stats().requests, 1);
    }

    #[test]
    fn unknown_model_and_bad_arity_are_reported() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let err = client.infer("ghost", vec![0.0; 8]).unwrap_err();
        assert!(err.contains("not registered"), "{err}");
        let err = client.infer("toy", vec![0.0; 3]).unwrap_err();
        assert!(err.contains("features"), "{err}");
        server.stop();
        assert_eq!(server.stats().errors, 2);
        // after stop, requests fail cleanly instead of hanging
        assert!(client.infer("toy", vec![0.0; 8]).is_err());
    }

    #[test]
    fn stats_handle_outlives_the_server() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        client.infer("toy", vec![0.0; 8]).unwrap();
        let handle = server.stats_handle();
        server.stop();
        drop(server);
        // the shared stats remain readable after stop + drop
        let snap = handle.snapshot();
        assert_eq!(snap.requests, 1);
        assert!(handle.to_json().get("requests").is_some());
    }

    #[test]
    fn outcome_carries_pipeline_spans() {
        let (reg, _) = toy_registry();
        let mut server = MicroBatchServer::start(reg, ServerConfig::default());
        let client = server.client();
        let (reply_tx, reply_rx) = mpsc::channel();
        client.submit("toy", vec![0.0; 8], reply_tx).unwrap();
        let o = reply_rx.recv().unwrap();
        assert!(o.result.is_ok());
        assert_eq!(o.batch_size, 1);
        // spans are measured (compute covers a real forward pass; queue
        // wait covers at least the max_wait coalescing window)
        assert!(o.compute_ns > 0);
        assert!(o.queue_ns > 0);
        server.stop();
    }
}
