//! L3 serving subsystem: ship and execute the *compressed* net.
//!
//! The LC coordinator's deliverable is Θ = (codebook, assignments) — yet
//! until this module existed the repo only kept the dense expansion
//! `wc = Δ(Θ)`. `serve` closes the loop with the paper's deployment story:
//!
//! * [`packed`] — the [`PackedModel`] artifact. Storage is exactly what
//!   §5's eq. (14) counts: P1 weights at ⌈log₂K⌉ bits each, plus K f32
//!   codebook entries per layer and the f32 biases (P0). So
//!   [`PackedModel::compression_ratio`] reproduces the paper's ρ(K)
//!   numbers (×30.5 for LeNet300 at K=2, etc.) *as measured on disk*, not
//!   just in a formula.
//! * [`format`] — versioned little-endian binary `.lcq` files (v2:
//!   64-byte-aligned, per-section FNV-checksummed plane sections behind a
//!   checksummed header); corruption and truncation fail loudly — at load
//!   on the eager path, on first touch on the zero-copy
//!   [`PackedModel::load_mmap`] path, which serves plane words straight
//!   from the page cache with lazy per-section verification.
//! * [`engine`] — the [`LutEngine`] forward pass off the packed form,
//!   realizing §2.1's hardware argument (additions and lookups instead of
//!   one multiply per weight) in two tiers selected by [`EngineMode`]:
//!   **bit-sliced** kernels ([`bitslice`]) that compute popcount-style
//!   masked sums, gather-free K-accumulators and exponent-shift combines
//!   *directly on the packed `u64` plane words*, and the per-centroid
//!   **LUT gather** tier for layers outside the bit-sliced envelope.
//!   Exact-zero centroids cost nothing on either tier.
//! * [`bitslice`] — the bit-sliced row kernels themselves, each pinned
//!   bit-for-bit to a scalar reference decomposition in
//!   [`crate::linalg::vecops`].
//! * [`server`] — a micro-batching, **pipelined** request queue
//!   ([`MicroBatchServer`]): single requests coalesce up to a deadline
//!   into engine-friendly batches, `pipeline_depth` executor threads run
//!   coalesced batches concurrently (their layer passes overlap on the
//!   multi-task worker pool), with p50/p90/p99 latency reporting.
//! * [`registry`] — a [`Registry`] of many packed variants of a net
//!   (binary / ternary / pow2 / adaptive-K), routed per-request by name,
//!   so one process serves a whole compression-tradeoff family.
//!
//! The `.lcq` byte-level format is specified for third-party readers in
//! `docs/lcq-format.md`; the surrounding dataflow (L step → C step → pack
//! → serve) is drawn out in `docs/ARCHITECTURE.md`. The network front end
//! that exposes this stack to remote clients over framed TCP is
//! [`crate::net`] (LCQ-RPC, `docs/wire-protocol.md`).
//!
//! ```no_run
//! use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
//! use std::sync::Arc;
//! # fn demo(lc: &lcquant::coordinator::LcResult, spec: &lcquant::nn::MlpSpec,
//! #         params: &lcquant::nn::ParamSet) -> anyhow::Result<()> {
//! // pack the LC result (biases come from the flat parameter arena) and
//! // save the deployable artifact
//! let model = PackedModel::from_lc("lenet300-k2", spec, lc, params)?;
//! model.save(std::path::Path::new("models/lenet300-k2.lcq"))?;
//! // later / elsewhere: load the family and serve
//! let registry = Arc::new(Registry::load_dir(std::path::Path::new("models"))?);
//! let server = MicroBatchServer::start(registry, ServerConfig::default());
//! let _logits = server.client().infer("lenet300-k2", vec![0.0; 784]);
//! # Ok(())
//! # }
//! ```
#![warn(missing_docs)]

pub mod bitslice;
pub mod engine;
pub mod format;
pub mod packed;
pub mod registry;
pub mod server;

pub use engine::{EngineMode, EngineScratch, LutEngine};
pub use packed::{PackedLayer, PackedModel, PlaneKind};
pub use registry::{LoadedModel, ModelInfo, Registry};
pub use server::{Client, JobOutcome, MicroBatchServer, ServeStats, ServerConfig, StatsSnapshot};
