//! The packed model artifact: per-layer codebook assignments stored as
//! column-major bit **planes** in `u64` words, plus the codebook, biases
//! and architecture — exactly the storage the paper's compression-ratio
//! formula (eq. 14) counts, so [`PackedModel::payload_bits`] agrees with
//! [`crate::quant::ratio::quantized_bits`] bit for bit.
//!
//! # Plane layouts
//!
//! Assignments are packed **per output column** so the bit-sliced serve
//! tier ([`crate::serve::bitslice`]) can run popcount kernels straight
//! over a column's words. Each column occupies
//! [`PackedLayer::words_per_column`] consecutive `u64`s; layouts by
//! [`PlaneKind`]:
//!
//! * **`Sign`** (symmetric binary codebook `[-a, +a]`): one plane, one
//!   bit per weight. Bit `r % 64` of word `c·wpc + r/64` is weight
//!   `(r, c)`; a set bit means centroid index 1 (`+a`).
//! * **`SignMask`** (symmetric ternary codebook `[-a, 0, +a]`): two
//!   planes with the `Sign` bit geometry. Plane 0 is the *sign* plane (set
//!   = `+a` among the nonzero weights), plane 1 is the *nonzero mask*
//!   (set = weight is `±a`, clear = the 0 centroid). Packing maintains
//!   sign ⊆ mask; consumers intersect the planes, so the mask stays
//!   authoritative even for hostile inputs.
//! * **`Coded`** (everything else): one plane of ⌈log₂K⌉-bit codes,
//!   LSB-first within a column — the code for row `r` of column `c`
//!   starts at column-local bit offset `r·bits` and may straddle a word
//!   boundary.
//!
//! Unused bits of a column's last word are zero. `K = 1` layers
//! (`bits == 0`) have no planes at all.
//!
//! # Plane storage and lazy verification
//!
//! Plane words live in [`Words`] handles that either own a `Vec<u64>`
//! (freshly packed / eagerly loaded, already validated) or borrow a
//! section of an mmap'd `.lcq` file ([`crate::util::mmap::MmapRegion`]).
//! Mapped sections carry their expected FNV-1a checksum and are verified
//! **lazily on first touch** by [`Words::verify`] — the cold-load path
//! never streams the payload, so model load cost is (number of planes) ×
//! header bytes, not file size.

use crate::coordinator::LcResult;
use crate::nn::params::ParamSet;
use crate::nn::{Mlp, MlpSpec};
use crate::obs::{self, CounterId};
use crate::quant::ratio::{self, bits_per_weight};
use crate::quant::Scheme;
use crate::util::mmap::MmapRegion;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// How a layer's assignments are laid out in planes (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneKind {
    /// One plane of ⌈log₂K⌉-bit codes per column.
    Coded,
    /// One 1-bit sign plane (symmetric binary codebook).
    Sign,
    /// Sign + nonzero-mask planes (symmetric ternary codebook).
    SignMask,
}

impl PlaneKind {
    /// Decide the layout from the codebook shape alone — the decision is
    /// therefore stable across pack → save → load regardless of scheme
    /// metadata. Codebooks come out of the C step sorted ascending.
    pub fn for_codebook(cb: &[f32]) -> PlaneKind {
        if cb.len() == 2 && cb[0] == -cb[1] && cb[1] > 0.0 {
            PlaneKind::Sign
        } else if cb.len() == 3 && cb[1] == 0.0 && cb[0] == -cb[2] && cb[2] > 0.0 {
            PlaneKind::SignMask
        } else {
            PlaneKind::Coded
        }
    }
}

/// [`Words`] verification state: not yet checked against its checksum.
const STATE_UNVERIFIED: u8 = 0;
/// [`Words`] verification state: checksum matched (or owned data).
const STATE_VERIFIED: u8 = 1;
/// [`Words`] verification state: checksum mismatch — section is corrupt.
const STATE_CORRUPT: u8 = 2;

enum Storage {
    /// Owned words (freshly packed, or eagerly parsed + validated).
    Owned(Vec<u64>),
    /// A section of a mapped `.lcq` file: `n_words` little-endian words
    /// at `offset` bytes (8-byte aligned; the format aligns sections to
    /// 64). Only constructed on little-endian targets, where the byte
    /// view *is* the word view.
    Mapped { region: Arc<MmapRegion>, offset: usize, n_words: usize },
}

struct WordsInner {
    storage: Storage,
    /// Expected FNV-1a of the section bytes; `None` for pre-verified
    /// owned words.
    expected_fnv: Option<u64>,
    /// One of the `STATE_*` constants. Relaxed ordering everywhere: the
    /// words themselves are immutable, the state is a memo, and a
    /// concurrent double-verify is benign (both sides compute the same
    /// verdict).
    state: AtomicU8,
}

/// A shareable, cheaply clonable handle to one plane's `u64` words, with
/// lazy per-section checksum verification (see module docs).
#[derive(Clone)]
pub struct Words {
    inner: Arc<WordsInner>,
}

impl Words {
    /// Wrap owned, already-trusted words (no checksum, pre-verified).
    pub(crate) fn owned(words: Vec<u64>) -> Words {
        Words {
            inner: Arc::new(WordsInner {
                storage: Storage::Owned(words),
                expected_fnv: None,
                state: AtomicU8::new(STATE_VERIFIED),
            }),
        }
    }

    /// Wrap a mapped file section, to be verified lazily against
    /// `expected_fnv` on first [`Words::verify`]. `offset` must be
    /// 8-byte aligned and in bounds (the format reader validates both,
    /// plus the 64-byte section alignment, before constructing this).
    pub(crate) fn mapped(
        region: Arc<MmapRegion>,
        offset: usize,
        n_words: usize,
        expected_fnv: u64,
    ) -> Words {
        assert!(offset % 8 == 0, "plane section offset must be word-aligned");
        assert!(
            offset + n_words * 8 <= region.len(),
            "plane section out of file bounds"
        );
        Words {
            inner: Arc::new(WordsInner {
                storage: Storage::Mapped { region, offset, n_words },
                expected_fnv: Some(expected_fnv),
                state: AtomicU8::new(STATE_UNVERIFIED),
            }),
        }
    }

    /// Number of words.
    pub fn len(&self) -> usize {
        match &self.inner.storage {
            Storage::Owned(v) => v.len(),
            Storage::Mapped { n_words, .. } => *n_words,
        }
    }

    /// Whether the plane holds no words.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this plane is served from a file mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(self.inner.storage, Storage::Mapped { .. })
    }

    /// The section bytes (the unit the checksum covers).
    fn section_bytes(&self) -> &[u8] {
        match &self.inner.storage {
            Storage::Owned(v) => {
                // SAFETY: the Vec owns v.len()*8 initialized bytes.
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 8) }
            }
            Storage::Mapped { region, offset, n_words } => {
                &region.bytes()[*offset..*offset + n_words * 8]
            }
        }
    }

    /// The words **without** checksum verification. Hot accessors
    /// ([`PackedLayer::assignment`], bulk unpack) use this; serving paths
    /// call [`Words::verify`] once per layer pass first, so a corrupt
    /// mapped section is rejected before its garbage is ever interpreted.
    pub fn raw(&self) -> &[u64] {
        match &self.inner.storage {
            Storage::Owned(v) => v,
            Storage::Mapped { region, offset, n_words } => {
                let bytes = &region.bytes()[*offset..*offset + n_words * 8];
                debug_assert_eq!(bytes.as_ptr() as usize % 8, 0);
                // SAFETY: in-bounds (checked at construction), 8-byte
                // aligned (aligned offset + 8-byte-aligned region base),
                // immutable for the region's lifetime; only constructed
                // on little-endian targets so the words read correctly.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u64, *n_words) }
            }
        }
    }

    /// The words, checksum-verified: on first touch of a mapped section
    /// the FNV-1a of its bytes is computed and compared (counted as
    /// `lcq_section_verifies`); later calls reuse the memoized verdict
    /// (`lcq_lazy_verify_hits`). A mismatch is sticky — every subsequent
    /// call keeps failing.
    pub fn verify(&self) -> Result<&[u64]> {
        match self.inner.state.load(Ordering::Relaxed) {
            STATE_VERIFIED => {
                if self.inner.expected_fnv.is_some() && obs::enabled() {
                    obs::counter(CounterId::LcqLazyVerifyHits).inc();
                }
                Ok(self.raw())
            }
            STATE_CORRUPT => Err(anyhow!("plane section checksum mismatch (corrupt .lcq data)")),
            _ => {
                let expected =
                    self.inner.expected_fnv.expect("unverified plane must carry a checksum");
                if obs::enabled() {
                    obs::counter(CounterId::LcqSectionVerifies).inc();
                }
                let ok = crate::serve::format::fnv1a(self.section_bytes()) == expected;
                self.inner
                    .state
                    .store(if ok { STATE_VERIFIED } else { STATE_CORRUPT }, Ordering::Relaxed);
                if ok {
                    Ok(self.raw())
                } else {
                    Err(anyhow!("plane section checksum mismatch (corrupt .lcq data)"))
                }
            }
        }
    }
}

impl PartialEq for Words {
    fn eq(&self, other: &Words) -> bool {
        self.raw() == other.raw()
    }
}

impl std::fmt::Debug for Words {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Words")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// Mask selecting the valid (row-covering) low bits of a 1-bit plane
/// word whose block holds `n_b ≤ 64` rows.
#[inline(always)]
fn valid_mask(n_b: usize) -> u64 {
    if n_b >= 64 {
        !0
    } else {
        (1u64 << n_b) - 1
    }
}

/// One layer: `rows × cols` assignments packed into column-major bit
/// planes (see module docs), a K-entry codebook, and the full-precision
/// bias.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedLayer {
    /// Input dimension (weight matrix rows).
    pub rows: usize,
    /// Output dimension (weight matrix cols).
    pub cols: usize,
    /// Bits per assignment: ⌈log₂K⌉ (0 when K = 1).
    pub bits: usize,
    /// Plane layout, decided from the codebook shape at pack time.
    pub kind: PlaneKind,
    /// The K codebook entries (sorted ascending, as the C step emits them).
    pub codebook: Vec<f32>,
    /// Full-precision bias (paper §5: biases are not quantized).
    pub bias: Vec<f32>,
    /// The assignment planes (`kind`-dependent count; empty when
    /// `bits == 0`).
    pub(crate) planes: Vec<Words>,
}

impl PackedLayer {
    /// Pack assignment indices for one layer.
    pub fn pack(
        rows: usize,
        cols: usize,
        codebook: Vec<f32>,
        bias: Vec<f32>,
        assignments: &[u32],
    ) -> Result<PackedLayer> {
        let n = rows * cols;
        if assignments.len() != n {
            return Err(anyhow!(
                "layer {rows}x{cols}: {} assignments, expected {n}",
                assignments.len()
            ));
        }
        if codebook.is_empty() {
            return Err(anyhow!("empty codebook"));
        }
        if bias.len() != cols {
            return Err(anyhow!("bias len {} != cols {cols}", bias.len()));
        }
        let k = codebook.len();
        if let Some(&bad) = assignments.iter().find(|&&a| a as usize >= k) {
            return Err(anyhow!("assignment {bad} out of range for K={k}"));
        }
        let bits = bits_per_weight(k);
        let kind = PlaneKind::for_codebook(&codebook);
        let wpc = Self::wpc(kind, rows, bits);
        let planes = if bits == 0 {
            Vec::new()
        } else {
            match kind {
                PlaneKind::Sign => {
                    let mut sign = vec![0u64; cols * wpc];
                    for (i, &a) in assignments.iter().enumerate() {
                        if a == 1 {
                            let (r, c) = (i / cols, i % cols);
                            sign[c * wpc + r / 64] |= 1u64 << (r % 64);
                        }
                    }
                    vec![Words::owned(sign)]
                }
                PlaneKind::SignMask => {
                    let mut sign = vec![0u64; cols * wpc];
                    let mut mask = vec![0u64; cols * wpc];
                    for (i, &a) in assignments.iter().enumerate() {
                        if a != 1 {
                            let (r, c) = (i / cols, i % cols);
                            let (w, b) = (c * wpc + r / 64, r % 64);
                            mask[w] |= 1u64 << b;
                            if a == 2 {
                                sign[w] |= 1u64 << b;
                            }
                        }
                    }
                    vec![Words::owned(sign), Words::owned(mask)]
                }
                PlaneKind::Coded => {
                    let mut words = vec![0u64; cols * wpc];
                    for (i, &a) in assignments.iter().enumerate() {
                        let (r, c) = (i / cols, i % cols);
                        let bitpos = r * bits;
                        let (w, off) = (c * wpc + bitpos / 64, bitpos % 64);
                        words[w] |= (a as u64) << off;
                        if off + bits > 64 {
                            words[w + 1] |= (a as u64) >> (64 - off);
                        }
                    }
                    vec![Words::owned(words)]
                }
            }
        };
        Ok(PackedLayer { rows, cols, bits, kind, codebook, bias, planes })
    }

    /// `u64` words each column occupies in a plane.
    pub fn words_per_column(&self) -> usize {
        Self::wpc(self.kind, self.rows, self.bits)
    }

    fn wpc(kind: PlaneKind, rows: usize, bits: usize) -> usize {
        if bits == 0 {
            return 0;
        }
        match kind {
            PlaneKind::Sign | PlaneKind::SignMask => rows.div_ceil(64),
            PlaneKind::Coded => (rows * bits).div_ceil(64),
        }
    }

    /// Number of planes this layer stores (0 when `bits == 0`, 2 for
    /// `SignMask`, 1 otherwise).
    pub fn n_planes(&self) -> usize {
        self.planes.len()
    }

    /// The raw plane handles (unverified access; serving paths go through
    /// [`PackedLayer::plane_words`]).
    pub fn planes(&self) -> &[Words] {
        &self.planes
    }

    /// Plane `p`'s words, checksum-verified ([`Words::verify`]).
    pub fn plane_words(&self, p: usize) -> Result<&[u64]> {
        self.planes[p].verify()
    }

    /// Number of weights (P1 contribution) in this layer.
    pub fn weight_count(&self) -> usize {
        self.rows * self.cols
    }

    /// Read one assignment (`i` is the row-major index `r·cols + c`,
    /// matching [`crate::linalg::Mat`]). Reads plane words without
    /// checksum verification — see [`Words::raw`].
    #[inline]
    pub fn assignment(&self, i: usize) -> u32 {
        debug_assert!(i < self.weight_count());
        if self.bits == 0 {
            return 0;
        }
        let (r, c) = (i / self.cols, i % self.cols);
        let wpc = self.words_per_column();
        match self.kind {
            PlaneKind::Sign => {
                ((self.planes[0].raw()[c * wpc + r / 64] >> (r % 64)) & 1) as u32
            }
            PlaneKind::SignMask => {
                let (w, b) = (c * wpc + r / 64, r % 64);
                if (self.planes[1].raw()[w] >> b) & 1 == 0 {
                    1 // the 0 centroid
                } else if (self.planes[0].raw()[w] >> b) & 1 == 1 {
                    2 // +a
                } else {
                    0 // -a
                }
            }
            PlaneKind::Coded => {
                let words = self.planes[0].raw();
                let bitpos = r * self.bits;
                let (w, off) = (c * wpc + bitpos / 64, bitpos % 64);
                let mut v = words[w] >> off;
                if off + self.bits > 64 {
                    v |= words[w + 1] << (64 - off);
                }
                (v & ((1u64 << self.bits) - 1)) as u32
            }
        }
    }

    /// Unpack every assignment index (row-major), word at a time: each
    /// plane word is loaded once and its bits streamed out, instead of
    /// re-deriving word/offset per index as [`PackedLayer::assignment`]
    /// does. Bit planes only write their set bits (via
    /// `trailing_zeros`); the coded plane streams each column through a
    /// 128-bit refill buffer. Reads plane words without checksum
    /// verification — use [`PackedLayer::try_unpack_assignments`] for
    /// untrusted mapped data.
    pub fn unpack_assignments(&self) -> Vec<u32> {
        let n = self.weight_count();
        if self.bits == 0 {
            return vec![0u32; n];
        }
        let (rows, cols) = (self.rows, self.cols);
        let wpc = self.words_per_column();
        match self.kind {
            PlaneKind::Sign => {
                let mut out = vec![0u32; n];
                let words = self.planes[0].raw();
                for c in 0..cols {
                    for wi in 0..wpc {
                        // mask to the row-covering bits so hostile padding
                        // bits can't index past `rows`
                        let mut w = words[c * wpc + wi] & valid_mask(rows - wi * 64);
                        while w != 0 {
                            let r = wi * 64 + w.trailing_zeros() as usize;
                            out[r * cols + c] = 1;
                            w &= w - 1;
                        }
                    }
                }
                out
            }
            PlaneKind::SignMask => {
                let mut out = vec![1u32; n]; // default: the 0 centroid
                let sign = self.planes[0].raw();
                let mask = self.planes[1].raw();
                for c in 0..cols {
                    for wi in 0..wpc {
                        let idx = c * wpc + wi;
                        let s = sign[idx];
                        let mut m = mask[idx] & valid_mask(rows - wi * 64);
                        while m != 0 {
                            let b = m.trailing_zeros();
                            let r = wi * 64 + b as usize;
                            out[r * cols + c] = if (s >> b) & 1 == 1 { 2 } else { 0 };
                            m &= m - 1;
                        }
                    }
                }
                out
            }
            PlaneKind::Coded => {
                let mut out = vec![0u32; n];
                let words = self.planes[0].raw();
                let m = (1u64 << self.bits) - 1;
                for c in 0..cols {
                    let col = &words[c * wpc..(c + 1) * wpc];
                    let mut buf: u128 = 0;
                    let mut avail = 0usize;
                    let mut next = 0usize;
                    for r in 0..rows {
                        if avail < self.bits {
                            buf |= (col[next] as u128) << avail;
                            next += 1;
                            avail += 64;
                        }
                        out[r * cols + c] = (buf as u64 & m) as u32;
                        buf >>= self.bits;
                        avail -= self.bits;
                    }
                }
                out
            }
        }
    }

    /// [`PackedLayer::unpack_assignments`] with every plane
    /// checksum-verified first — the form engine construction uses on
    /// mapped models.
    pub fn try_unpack_assignments(&self) -> Result<Vec<u32>> {
        for p in &self.planes {
            p.verify()?;
        }
        Ok(self.unpack_assignments())
    }

    /// Expand to dense f32 weights (row-major) — only for validation and
    /// interop; the serving path never calls this.
    pub fn unpack_weights(&self) -> Vec<f32> {
        self.unpack_assignments()
            .into_iter()
            .map(|a| self.codebook[a as usize])
            .collect()
    }
}

/// A deployable quantized net: the [`MlpSpec`], the [`Scheme`] it was
/// compressed with, and one [`PackedLayer`] per weight layer.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedModel {
    /// Registry key (e.g. `"lenet300-binary"`).
    pub name: String,
    /// Architecture of the packed net (layer sizes, hidden activation).
    pub spec: MlpSpec,
    /// Quantization scheme the net was compressed with (drives the LUT
    /// engine's sign/shift specializations at load).
    pub scheme: Scheme,
    /// One packed layer per weight layer, in forward order.
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Build from explicit per-layer parts.
    pub fn from_parts(
        name: &str,
        spec: &MlpSpec,
        scheme: &Scheme,
        codebooks: &[Vec<f32>],
        assignments: &[Vec<u32>],
        biases: &[Vec<f32>],
    ) -> Result<PackedModel> {
        let n_layers = spec.n_layers();
        if codebooks.len() != n_layers || assignments.len() != n_layers || biases.len() != n_layers
        {
            return Err(anyhow!(
                "layer count mismatch: spec {n_layers}, codebooks {}, assignments {}, biases {}",
                codebooks.len(),
                assignments.len(),
                biases.len()
            ));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            layers.push(PackedLayer::pack(
                spec.sizes[l],
                spec.sizes[l + 1],
                codebooks[l].clone(),
                biases[l].clone(),
                &assignments[l],
            )?);
        }
        Ok(PackedModel {
            name: name.to_string(),
            spec: spec.clone(),
            scheme: scheme.clone(),
            layers,
        })
    }

    /// Package an [`LcResult`] — the final C step's assignments go straight
    /// into the bit-packing, no re-quantization of the dense weights. The
    /// full-precision biases are read as per-layer views of the backend's
    /// flat [`ParamSet`] arena (paper §5: biases are not quantized).
    pub fn from_lc(
        name: &str,
        spec: &MlpSpec,
        lc: &LcResult,
        params: &ParamSet,
    ) -> Result<PackedModel> {
        let n_layers = spec.n_layers();
        if params.layout().n_layers() != n_layers {
            return Err(anyhow!(
                "param arena has {} layers, spec {n_layers}",
                params.layout().n_layers()
            ));
        }
        let biases: Vec<Vec<f32>> =
            (0..n_layers).map(|l| params.b_layer(l).to_vec()).collect();
        PackedModel::from_parts(name, spec, &lc.scheme, &lc.codebooks, &lc.assignments, &biases)
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Expand every layer to dense f32 (validation/interop only).
    pub fn unpack_weights(&self) -> Vec<Vec<f32>> {
        self.layers.iter().map(|l| l.unpack_weights()).collect()
    }

    /// Rebuild a dense [`Mlp`] (the baseline the LUT engine is checked
    /// against).
    pub fn to_mlp(&self) -> Mlp {
        let weights = self.unpack_weights();
        let biases: Vec<Vec<f32>> = self.layers.iter().map(|l| l.bias.clone()).collect();
        Mlp::from_parts(&self.spec, &weights, &biases)
    }

    /// Stored bits under eq. (14)'s accounting: Σ_l P1_l·⌈log₂K_l⌉ +
    /// (P0 + Σ_l K_l)·b. Equals
    /// [`ratio::quantized_bits`]`(P1, P0, K, n_layers)` when every layer
    /// shares one K. (The plane layout stores exactly `bits` payload bits
    /// per weight — `SignMask`'s two 1-bit planes are ⌈log₂3⌉ = 2 —
    /// column padding words are format overhead, not payload.)
    pub fn payload_bits(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.weight_count() * l.bits + (l.bias.len() + l.codebook.len()) * ratio::FLOAT_BITS
            })
            .sum()
    }

    /// Bits of the float32 reference net with the same architecture.
    pub fn reference_bits(&self) -> usize {
        let (p1, p0) = self.spec.param_counts();
        ratio::reference_bits(p1, p0)
    }

    /// ρ = reference bits / packed bits (paper eq. 14).
    pub fn compression_ratio(&self) -> f64 {
        self.reference_bits() as f64 / self.payload_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::quant::LayerQuantizer;
    use crate::util::prop::check;

    fn toy_spec(sizes: Vec<usize>) -> MlpSpec {
        MlpSpec { sizes, hidden_activation: Activation::Tanh, dropout_keep: vec![] }
    }

    /// Quantize random weights with a scheme, pack, and return both.
    fn packed_from_scheme(
        scheme: &Scheme,
        spec: &MlpSpec,
        seed: u64,
    ) -> (PackedModel, Vec<Vec<f32>>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        let mut wcs = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let mut q = LayerQuantizer::new(scheme.clone(), seed + l as u64);
            let out = q.compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            wcs.push(out.wc);
            biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
        }
        let m = PackedModel::from_parts("toy", spec, scheme, &codebooks, &assignments, &biases)
            .unwrap();
        (m, wcs)
    }

    fn all_schemes(k: usize) -> Vec<Scheme> {
        vec![
            Scheme::AdaptiveCodebook { k },
            Scheme::AdaptiveWithZero { k: k.max(2) },
            Scheme::FixedCodebook {
                codebook: (0..k).map(|i| -1.0 + 2.0 * i as f32 / k as f32).collect(),
            },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 2 },
        ]
    }

    #[test]
    fn pack_unpack_identity_all_schemes_and_k() {
        // the tentpole round-trip: pack → unpack reproduces wc exactly,
        // for every Scheme variant and K ∈ {2, 3, 4, 5, 16, 256}
        let spec = toy_spec(vec![9, 7, 4]);
        let mut seed = 100;
        for k in [2usize, 3, 4, 5, 16, 256] {
            for scheme in all_schemes(k) {
                seed += 1;
                let (m, wcs) = packed_from_scheme(&scheme, &spec, seed);
                assert_eq!(m.unpack_weights(), wcs, "{scheme:?} K={k}");
            }
        }
    }

    #[test]
    fn packing_is_property_tested() {
        check("pack roundtrip", 60, |g| {
            let k = g.usize_in(1, 40);
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 20);
            let codebook: Vec<f32> = (0..k).map(|i| i as f32 * 0.25 - 1.0).collect();
            let assignments: Vec<u32> =
                (0..rows * cols).map(|_| g.usize_in(0, k - 1) as u32).collect();
            let bias = vec![0.0f32; cols];
            let layer = PackedLayer::pack(rows, cols, codebook, bias, &assignments).unwrap();
            assert_eq!(layer.unpack_assignments(), assignments);
            assert_eq!(layer.bits, bits_per_weight(k));
        });
    }

    #[test]
    fn plane_kind_follows_codebook_shape() {
        assert_eq!(PlaneKind::for_codebook(&[-0.7, 0.7]), PlaneKind::Sign);
        assert_eq!(PlaneKind::for_codebook(&[-0.7, 0.0, 0.7]), PlaneKind::SignMask);
        // asymmetric, zero-scale, or larger codebooks stay coded
        assert_eq!(PlaneKind::for_codebook(&[-0.7, 0.9]), PlaneKind::Coded);
        assert_eq!(PlaneKind::for_codebook(&[0.0, 0.0]), PlaneKind::Coded);
        assert_eq!(PlaneKind::for_codebook(&[-0.7, 0.1, 0.7]), PlaneKind::Coded);
        assert_eq!(PlaneKind::for_codebook(&[-1.0, -0.5, 0.5, 1.0]), PlaneKind::Coded);
        assert_eq!(PlaneKind::for_codebook(&[0.5]), PlaneKind::Coded);
        // schemes land on the expected layouts end to end
        let spec = toy_spec(vec![9, 7, 4]);
        let (m, _) = packed_from_scheme(&Scheme::Binary, &spec, 41);
        assert!(m.layers.iter().all(|l| l.kind == PlaneKind::Sign && l.n_planes() == 1));
        let (m, _) = packed_from_scheme(&Scheme::TernaryScale, &spec, 42);
        assert!(m.layers.iter().all(|l| l.kind == PlaneKind::SignMask && l.n_planes() == 2));
        let (m, _) = packed_from_scheme(&Scheme::AdaptiveCodebook { k: 4 }, &spec, 43);
        assert!(m.layers.iter().all(|l| l.kind == PlaneKind::Coded && l.n_planes() == 1));
    }

    #[test]
    fn column_major_plane_layout_is_pinned() {
        // 3×2 sign layer: weight (r, c) lives at bit r of word c (wpc = 1)
        let a = [1u32, 0, 0, 1, 1, 1]; // row-major: (0,0)=1 (0,1)=0 (1,0)=0 (1,1)=1 (2,0)=1 (2,1)=1
        let l = PackedLayer::pack(3, 2, vec![-0.5, 0.5], vec![0.0; 2], &a).unwrap();
        assert_eq!(l.kind, PlaneKind::Sign);
        assert_eq!(l.words_per_column(), 1);
        assert_eq!(l.planes()[0].raw(), &[0b101u64, 0b110]); // col 0: rows 0,2; col 1: rows 1,2
        // ternary: sign ⊆ mask by construction
        // row-major 3×2: (0,0)=-a (0,1)=0 (1,0)=+a (1,1)=0 (2,0)=+a (2,1)=-a
        let a = [0u32, 1, 2, 1, 2, 0];
        let l = PackedLayer::pack(3, 2, vec![-0.5, 0.0, 0.5], vec![0.0; 2], &a).unwrap();
        let sign = l.planes()[0].raw();
        let mask = l.planes()[1].raw();
        assert_eq!(mask, &[0b111u64, 0b100]); // col 0: all nonzero; col 1: row 2 only
        assert_eq!(sign, &[0b110u64, 0b000]); // +a at col 0 rows 1,2
        for (s, m) in sign.iter().zip(mask) {
            assert_eq!(s & !m, 0, "sign plane must be a subset of the mask plane");
        }
        // coded, bits=3, 50 rows × 1 col: 150 bits → 3 words per column
        let k = 5;
        let assignments: Vec<u32> = (0..50).map(|i| (i * 7 % k) as u32).collect();
        let codebook: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let l = PackedLayer::pack(50, 1, codebook, vec![0.0], &assignments).unwrap();
        assert_eq!((l.kind, l.bits), (PlaneKind::Coded, 3));
        assert_eq!(l.words_per_column(), 3);
        assert_eq!(l.planes()[0].len(), 3);
        assert_eq!(l.unpack_assignments(), assignments);
    }

    #[test]
    fn bulk_unpack_matches_per_index_assignment() {
        check("bulk unpack == assignment()", 40, |g| {
            let rows = g.usize_in(1, 140); // straddles the 64-row word boundary
            let cols = g.usize_in(1, 6);
            let (codebook, k): (Vec<f32>, usize) = match g.usize_in(0, 2) {
                0 => (vec![-0.5, 0.5], 2),
                1 => (vec![-0.5, 0.0, 0.5], 3),
                _ => {
                    let k = g.usize_in(4, 9);
                    ((0..k).map(|i| i as f32 * 0.3 - 1.0).collect(), k)
                }
            };
            let assignments: Vec<u32> =
                (0..rows * cols).map(|_| g.usize_in(0, k - 1) as u32).collect();
            let l =
                PackedLayer::pack(rows, cols, codebook, vec![0.0; cols], &assignments).unwrap();
            let bulk = l.unpack_assignments();
            assert_eq!(bulk, assignments);
            for i in 0..rows * cols {
                assert_eq!(l.assignment(i), assignments[i], "i={i}");
            }
            assert_eq!(l.try_unpack_assignments().unwrap(), assignments);
        });
    }

    #[test]
    fn payload_bits_match_ratio_accounting() {
        // eq. (14): on-disk payload for uniform K equals quantized_bits()
        let spec = toy_spec(vec![30, 20, 10]);
        let (p1, p0) = spec.param_counts();
        for k in [2usize, 3, 4, 5, 16, 256] {
            let (m, _) = packed_from_scheme(&Scheme::AdaptiveCodebook { k }, &spec, 7);
            assert_eq!(
                m.payload_bits(),
                ratio::quantized_bits(p1, p0, k, spec.n_layers()),
                "K={k}"
            );
            let rho = m.compression_ratio();
            let expect = ratio::compression_ratio(p1, p0, k, spec.n_layers());
            assert!((rho - expect).abs() < 1e-12, "K={k}: {rho} vs {expect}");
        }
        // the symmetric layouts keep eq.-14 accounting too: ⌈log₂2⌉ = 1
        // bit (Sign), ⌈log₂3⌉ = 2 bits (SignMask's two 1-bit planes)
        let (m, _) = packed_from_scheme(&Scheme::Binary, &spec, 8);
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 2, spec.n_layers()));
        let (m, _) = packed_from_scheme(&Scheme::Ternary, &spec, 9);
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 3, spec.n_layers()));
    }

    #[test]
    fn to_mlp_reproduces_quantized_forward() {
        let spec = toy_spec(vec![6, 5, 3]);
        let (m, wcs) = packed_from_scheme(&Scheme::AdaptiveCodebook { k: 4 }, &spec, 9);
        let net = m.to_mlp();
        assert_eq!(net.weights_cloned(), wcs);
        for (l, pl) in m.layers.iter().enumerate() {
            assert_eq!(net.bias(l), pl.bias.as_slice());
        }
    }

    #[test]
    fn pack_rejects_bad_shapes() {
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 2], &[0, 1, 0]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![], vec![0.0; 2], &[0; 4]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 3], &[0; 4]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 2], &[0, 1, 2, 0]).is_err());
    }

    #[test]
    fn k1_packs_to_zero_bits() {
        let layer = PackedLayer::pack(3, 2, vec![0.5], vec![0.0; 2], &[0; 6]).unwrap();
        assert_eq!(layer.bits, 0);
        assert_eq!(layer.n_planes(), 0);
        assert_eq!(layer.words_per_column(), 0);
        assert_eq!(layer.unpack_weights(), vec![0.5f32; 6]);
    }
}
