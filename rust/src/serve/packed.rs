//! The packed model artifact: per-layer bit-packed codebook assignments at
//! ⌈log₂K⌉ bits per weight, plus the codebook, biases and architecture —
//! exactly the storage the paper's compression-ratio formula (eq. 14)
//! counts, so [`PackedModel::payload_bits`] agrees with
//! [`crate::quant::ratio::quantized_bits`] bit for bit.

use crate::coordinator::LcResult;
use crate::nn::params::ParamSet;
use crate::nn::{Mlp, MlpSpec};
use crate::quant::ratio::{self, bits_per_weight};
use crate::quant::Scheme;
use anyhow::{anyhow, Result};

/// One layer: `rows × cols` assignments bit-packed into `u64` words
/// (row-major, matching [`crate::linalg::Mat`] layout, LSB-first within a
/// word), a K-entry codebook, and the full-precision bias.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedLayer {
    /// Input dimension (weight matrix rows).
    pub rows: usize,
    /// Output dimension (weight matrix cols).
    pub cols: usize,
    /// Bits per assignment: ⌈log₂K⌉ (0 when K = 1).
    pub bits: usize,
    /// The K codebook entries (sorted ascending, as the C step emits them).
    pub codebook: Vec<f32>,
    /// Full-precision bias (paper §5: biases are not quantized).
    pub bias: Vec<f32>,
    /// Bit-packed assignments, `⌈rows·cols·bits / 64⌉` words.
    pub packed: Vec<u64>,
}

impl PackedLayer {
    /// Pack assignment indices for one layer.
    pub fn pack(
        rows: usize,
        cols: usize,
        codebook: Vec<f32>,
        bias: Vec<f32>,
        assignments: &[u32],
    ) -> Result<PackedLayer> {
        let n = rows * cols;
        if assignments.len() != n {
            return Err(anyhow!(
                "layer {rows}x{cols}: {} assignments, expected {n}",
                assignments.len()
            ));
        }
        if codebook.is_empty() {
            return Err(anyhow!("empty codebook"));
        }
        if bias.len() != cols {
            return Err(anyhow!("bias len {} != cols {cols}", bias.len()));
        }
        let k = codebook.len();
        let bits = bits_per_weight(k);
        let mut packed = vec![0u64; (n * bits).div_ceil(64)];
        for (i, &a) in assignments.iter().enumerate() {
            if a as usize >= k {
                return Err(anyhow!("assignment {a} out of range for K={k}"));
            }
            if bits == 0 {
                continue;
            }
            let bitpos = i * bits;
            let (word, off) = (bitpos / 64, bitpos % 64);
            packed[word] |= (a as u64) << off;
            if off + bits > 64 {
                packed[word + 1] |= (a as u64) >> (64 - off);
            }
        }
        Ok(PackedLayer { rows, cols, bits, codebook, bias, packed })
    }

    /// Number of weights (P1 contribution) in this layer.
    pub fn weight_count(&self) -> usize {
        self.rows * self.cols
    }

    /// Read one assignment.
    #[inline]
    pub fn assignment(&self, i: usize) -> u32 {
        debug_assert!(i < self.weight_count());
        if self.bits == 0 {
            return 0;
        }
        let mask = (1u64 << self.bits) - 1;
        let bitpos = i * self.bits;
        let (word, off) = (bitpos / 64, bitpos % 64);
        let mut v = self.packed[word] >> off;
        if off + self.bits > 64 {
            v |= self.packed[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Unpack every assignment index.
    pub fn unpack_assignments(&self) -> Vec<u32> {
        (0..self.weight_count()).map(|i| self.assignment(i)).collect()
    }

    /// Expand to dense f32 weights (row-major) — only for validation and
    /// interop; the serving path never calls this.
    pub fn unpack_weights(&self) -> Vec<f32> {
        (0..self.weight_count())
            .map(|i| self.codebook[self.assignment(i) as usize])
            .collect()
    }
}

/// A deployable quantized net: the [`MlpSpec`], the [`Scheme`] it was
/// compressed with, and one [`PackedLayer`] per weight layer.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedModel {
    /// Registry key (e.g. `"lenet300-binary"`).
    pub name: String,
    /// Architecture of the packed net (layer sizes, hidden activation).
    pub spec: MlpSpec,
    /// Quantization scheme the net was compressed with (drives the LUT
    /// engine's sign/shift specializations at load).
    pub scheme: Scheme,
    /// One packed layer per weight layer, in forward order.
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Build from explicit per-layer parts.
    pub fn from_parts(
        name: &str,
        spec: &MlpSpec,
        scheme: &Scheme,
        codebooks: &[Vec<f32>],
        assignments: &[Vec<u32>],
        biases: &[Vec<f32>],
    ) -> Result<PackedModel> {
        let n_layers = spec.n_layers();
        if codebooks.len() != n_layers || assignments.len() != n_layers || biases.len() != n_layers
        {
            return Err(anyhow!(
                "layer count mismatch: spec {n_layers}, codebooks {}, assignments {}, biases {}",
                codebooks.len(),
                assignments.len(),
                biases.len()
            ));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            layers.push(PackedLayer::pack(
                spec.sizes[l],
                spec.sizes[l + 1],
                codebooks[l].clone(),
                biases[l].clone(),
                &assignments[l],
            )?);
        }
        Ok(PackedModel {
            name: name.to_string(),
            spec: spec.clone(),
            scheme: scheme.clone(),
            layers,
        })
    }

    /// Package an [`LcResult`] — the final C step's assignments go straight
    /// into the bit-packing, no re-quantization of the dense weights. The
    /// full-precision biases are read as per-layer views of the backend's
    /// flat [`ParamSet`] arena (paper §5: biases are not quantized).
    pub fn from_lc(
        name: &str,
        spec: &MlpSpec,
        lc: &LcResult,
        params: &ParamSet,
    ) -> Result<PackedModel> {
        let n_layers = spec.n_layers();
        if params.layout().n_layers() != n_layers {
            return Err(anyhow!(
                "param arena has {} layers, spec {n_layers}",
                params.layout().n_layers()
            ));
        }
        let biases: Vec<Vec<f32>> =
            (0..n_layers).map(|l| params.b_layer(l).to_vec()).collect();
        PackedModel::from_parts(name, spec, &lc.scheme, &lc.codebooks, &lc.assignments, &biases)
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Expand every layer to dense f32 (validation/interop only).
    pub fn unpack_weights(&self) -> Vec<Vec<f32>> {
        self.layers.iter().map(|l| l.unpack_weights()).collect()
    }

    /// Rebuild a dense [`Mlp`] (the baseline the LUT engine is checked
    /// against).
    pub fn to_mlp(&self) -> Mlp {
        let weights = self.unpack_weights();
        let biases: Vec<Vec<f32>> = self.layers.iter().map(|l| l.bias.clone()).collect();
        Mlp::from_parts(&self.spec, &weights, &biases)
    }

    /// Stored bits under eq. (14)'s accounting: Σ_l P1_l·⌈log₂K_l⌉ +
    /// (P0 + Σ_l K_l)·b. Equals
    /// [`ratio::quantized_bits`]`(P1, P0, K, n_layers)` when every layer
    /// shares one K.
    pub fn payload_bits(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.weight_count() * l.bits + (l.bias.len() + l.codebook.len()) * ratio::FLOAT_BITS
            })
            .sum()
    }

    /// Bits of the float32 reference net with the same architecture.
    pub fn reference_bits(&self) -> usize {
        let (p1, p0) = self.spec.param_counts();
        ratio::reference_bits(p1, p0)
    }

    /// ρ = reference bits / packed bits (paper eq. 14).
    pub fn compression_ratio(&self) -> f64 {
        self.reference_bits() as f64 / self.payload_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::quant::LayerQuantizer;
    use crate::util::prop::check;

    fn toy_spec(sizes: Vec<usize>) -> MlpSpec {
        MlpSpec { sizes, hidden_activation: Activation::Tanh, dropout_keep: vec![] }
    }

    /// Quantize random weights with a scheme, pack, and return both.
    fn packed_from_scheme(
        scheme: &Scheme,
        spec: &MlpSpec,
        seed: u64,
    ) -> (PackedModel, Vec<Vec<f32>>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        let mut wcs = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let mut q = LayerQuantizer::new(scheme.clone(), seed + l as u64);
            let out = q.compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            wcs.push(out.wc);
            biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
        }
        let m = PackedModel::from_parts("toy", spec, scheme, &codebooks, &assignments, &biases)
            .unwrap();
        (m, wcs)
    }

    fn all_schemes(k: usize) -> Vec<Scheme> {
        vec![
            Scheme::AdaptiveCodebook { k },
            Scheme::AdaptiveWithZero { k: k.max(2) },
            Scheme::FixedCodebook {
                codebook: (0..k).map(|i| -1.0 + 2.0 * i as f32 / k as f32).collect(),
            },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 2 },
        ]
    }

    #[test]
    fn pack_unpack_identity_all_schemes_and_k() {
        // the tentpole round-trip: pack → unpack reproduces wc exactly,
        // for every Scheme variant and K ∈ {2, 3, 4, 5, 16, 256}
        let spec = toy_spec(vec![9, 7, 4]);
        let mut seed = 100;
        for k in [2usize, 3, 4, 5, 16, 256] {
            for scheme in all_schemes(k) {
                seed += 1;
                let (m, wcs) = packed_from_scheme(&scheme, &spec, seed);
                assert_eq!(m.unpack_weights(), wcs, "{scheme:?} K={k}");
            }
        }
    }

    #[test]
    fn packing_is_property_tested() {
        check("pack roundtrip", 60, |g| {
            let k = g.usize_in(1, 40);
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 20);
            let codebook: Vec<f32> = (0..k).map(|i| i as f32 * 0.25 - 1.0).collect();
            let assignments: Vec<u32> =
                (0..rows * cols).map(|_| g.usize_in(0, k - 1) as u32).collect();
            let bias = vec![0.0f32; cols];
            let layer = PackedLayer::pack(rows, cols, codebook, bias, &assignments).unwrap();
            assert_eq!(layer.unpack_assignments(), assignments);
            assert_eq!(layer.bits, bits_per_weight(k));
        });
    }

    #[test]
    fn payload_bits_match_ratio_accounting() {
        // eq. (14): on-disk payload for uniform K equals quantized_bits()
        let spec = toy_spec(vec![30, 20, 10]);
        let (p1, p0) = spec.param_counts();
        for k in [2usize, 3, 4, 5, 16, 256] {
            let (m, _) = packed_from_scheme(&Scheme::AdaptiveCodebook { k }, &spec, 7);
            assert_eq!(
                m.payload_bits(),
                ratio::quantized_bits(p1, p0, k, spec.n_layers()),
                "K={k}"
            );
            let rho = m.compression_ratio();
            let expect = ratio::compression_ratio(p1, p0, k, spec.n_layers());
            assert!((rho - expect).abs() < 1e-12, "K={k}: {rho} vs {expect}");
        }
    }

    #[test]
    fn to_mlp_reproduces_quantized_forward() {
        let spec = toy_spec(vec![6, 5, 3]);
        let (m, wcs) = packed_from_scheme(&Scheme::AdaptiveCodebook { k: 4 }, &spec, 9);
        let net = m.to_mlp();
        assert_eq!(net.weights_cloned(), wcs);
        for (l, pl) in m.layers.iter().enumerate() {
            assert_eq!(net.bias(l), pl.bias.as_slice());
        }
    }

    #[test]
    fn pack_rejects_bad_shapes() {
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 2], &[0, 1, 0]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![], vec![0.0; 2], &[0; 4]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 3], &[0; 4]).is_err());
        assert!(PackedLayer::pack(2, 2, vec![0.0, 1.0], vec![0.0; 2], &[0, 1, 2, 0]).is_err());
    }

    #[test]
    fn k1_packs_to_zero_bits() {
        let layer = PackedLayer::pack(3, 2, vec![0.5], vec![0.0; 2], &[0; 6]).unwrap();
        assert_eq!(layer.bits, 0);
        assert!(layer.packed.is_empty());
        assert_eq!(layer.unpack_weights(), vec![0.5f32; 6]);
    }

    #[test]
    fn word_boundary_straddling() {
        // bits=3 over >64 bits exercises the two-word read/write path
        let k = 5; // 3 bits
        let assignments: Vec<u32> = (0..50).map(|i| (i * 7 % k) as u32).collect();
        let codebook: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let layer = PackedLayer::pack(50, 1, codebook, vec![0.0], &assignments).unwrap();
        assert_eq!(layer.bits, 3);
        assert_eq!(layer.packed.len(), 3); // 150 bits → 3 words
        assert_eq!(layer.unpack_assignments(), assignments);
    }
}
