//! Quantized inference engine: forward passes straight off the packed
//! representation — dense f32 weight matrices are never materialized.
//!
//! The core identity (paper §2.1's hardware argument): with w[i,j] =
//! c[a[i,j]], an output unit is
//!
//! ```text
//! y_j = b_j + Σ_i x_i·c[a_ij] = b_j + Σ_k c_k · (Σ_{i: a_ij = k} x_i)
//! ```
//!
//! so the inner loop is *additions into K per-centroid partial sums*,
//! followed by a K-entry combine — K multiplies per output unit instead
//! of one per weight. Two execution tiers realize the identity:
//!
//! * **Bit-sliced** ([`crate::serve::bitslice`], the default wherever a
//!   layer's planes permit): the partial sums are computed **directly on
//!   the packed `u64` plane words** — XNOR/popcount-style masked sums for
//!   binary, two-plane sign/mask reductions for ternary, gather-free
//!   K-accumulators for small coded codebooks, and an exponent-shift
//!   combine for power-of-two codebooks. No unpacking, no index gathers,
//!   ~32–64× less weight traffic than a gather list; with
//!   [`PackedModel::load_mmap`] the words stream zero-copy from the page
//!   cache, checksum-verified lazily on first touch (which is why every
//!   forward is fallible).
//! * **LUT gathers** (the v1 tier, kept for large-K layers and as the
//!   [`EngineMode::Lut`] reference): per-centroid index gathers built
//!   once at load. `Grouped` skips exactly-zero centroids, `Signed`
//!   stores only the positive group (`y = b + a·(2S⁺ − T)`), `Pow2`
//!   combines by exponent shifts.
//!
//! [`EngineMode`] selects the tier: `Auto` (default) bit-slices every
//! representable layer and falls back to LUT gathers for the rest
//! (`bits > `[`bitslice::MAX_CODED_BITS`], or K = 1); `Lut` and
//! `BitSliced` force a tier for A/B benchmarking (`BitSliced` still
//! falls back where no bit-sliced kernel exists, so it never errors on a
//! valid model). [`LutEngine::layer_paths`] reports what was chosen.
//!
//! # Pipelining
//!
//! Each layer pass submits its row bands as one task on the **multi-task**
//! worker pool ([`crate::linalg::pool`]), so when several requests are in
//! flight (the micro-batching server's `pipeline_depth` executors, or any
//! concurrent callers of [`LutEngine::forward`]), layer N of request A
//! overlaps layer M of request B: workers drain bands across all live
//! tasks instead of serializing whole forward passes behind a single task
//! slot. Steady-state engines should reuse an [`EngineScratch`] via
//! [`LutEngine::forward_into`] so concurrent passes also allocate nothing
//! for activations (the scratch now also carries the bit-sliced tier's
//! per-row block sums).
//!
//! # Pre-staged rows
//!
//! Only the first layer ever reads the batch input, row by row — so the
//! input rows never need to live in one contiguous matrix.
//! [`LutEngine::forward_rows_into`] takes a row accessor instead of a
//! `Mat`; the micro-batch server feeds it each request's decoded buffer in
//! place, which removes the last per-request copy from the serve hot path
//! (wire bytes → request `Vec<f32>` → engine, no batch-staging copy in
//! between).

use super::bitslice::{self, BitPath};
use super::packed::{PackedLayer, PackedModel, PlaneKind, Words};
use crate::linalg::{num_threads, pool, vecops, Mat};
use crate::nn::Activation;
use crate::quant::Scheme;
use anyhow::{anyhow, Result};

/// Total adds (batch · in · out) below which a layer forward stays
/// single-threaded. Row bands dispatch through the persistent worker pool
/// (a few µs, no spawns, no allocation — the per-request latency floor the
/// old ~50µs `thread::scope` spawns used to set is gone), but splitting a
/// batch still costs cache locality, so tiny layer passes stay serial:
/// batch 256 on LeNet300's 784×300 layer qualifies, a micro-batch through
/// the 100×10 layer does not.
const PAR_MIN_WORK: usize = 2_000_000;

/// Which execution tier [`LutEngine`] builds for each layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Bit-sliced kernels wherever the layer's planes permit, LUT gathers
    /// for the rest. The right choice outside A/B experiments.
    #[default]
    Auto,
    /// Force the v1 per-centroid gather tier everywhere.
    Lut,
    /// Force bit-sliced kernels; layers with no bit-sliced form (K = 1,
    /// `bits > `[`bitslice::MAX_CODED_BITS`]) still fall back to LUT.
    BitSliced,
}

impl EngineMode {
    /// Stable lowercase name (config files, stats wire payloads).
    pub fn name(self) -> &'static str {
        match self {
            EngineMode::Auto => "auto",
            EngineMode::Lut => "lut",
            EngineMode::BitSliced => "bitsliced",
        }
    }
}

impl std::str::FromStr for EngineMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<EngineMode> {
        match s {
            "auto" => Ok(EngineMode::Auto),
            "lut" => Ok(EngineMode::Lut),
            "bitsliced" => Ok(EngineMode::BitSliced),
            _ => Err(anyhow!("unknown engine mode {s:?} (auto|lut|bitsliced)")),
        }
    }
}

impl std::fmt::Display for EngineMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Multiply a finite f32 by 2^e via exponent arithmetic (the "shift path").
/// Falls back to a float multiply for zeros/subnormals/overflow.
#[inline]
pub fn mul_pow2(x: f32, e: i32) -> f32 {
    debug_assert!((-126..=127).contains(&e), "shift {e} outside f32 exponent range");
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    let ne = exp + e;
    if exp == 0 || exp == 0xff || ne <= 0 || ne >= 0xff {
        // zero, subnormal, inf/nan, or result leaves the normal range
        return x * f32::from_bits(((127 + e) as u32) << 23);
    }
    f32::from_bits((bits & 0x807f_ffff) | ((ne as u32) << 23))
}

/// Per-centroid gather structure for one layer (the LUT tier).
enum LutPath {
    /// `indices[offsets[j*k + c] .. offsets[j*k + c + 1]]` are the input
    /// rows assigned to centroid `c` in output column `j`.
    Grouped { indices: Vec<u32>, offsets: Vec<usize> },
    /// Positive-centroid rows per column; `y = b + scale·(2S⁺ − T)`.
    Signed { pos: Vec<u32>, offsets: Vec<usize>, scale: f32 },
    /// Grouped, with the combine done by exponent shifts: centroid `c` is
    /// `signs[c]·2^exps[c]` (`signs[c] == 0` marks the zero centroid).
    Pow2 { indices: Vec<u32>, offsets: Vec<usize>, exps: Vec<i32>, signs: Vec<f32> },
}

/// How one layer executes: gather lists, or the packed planes themselves.
enum Exec {
    Lut(LutPath),
    /// `planes` are shared handles onto the packed (possibly mmap'd)
    /// sections; verified once per layer pass, then read in place.
    Bit { path: BitPath, planes: Vec<Words>, wpc: usize },
}

struct EngineLayer {
    in_dim: usize,
    out_dim: usize,
    k: usize,
    bits: usize,
    codebook: Vec<f32>,
    bias: Vec<f32>,
    act: Activation,
    exec: Exec,
}

/// Group a layer's assignments by (output column, centroid): counting sort,
/// O(P). Returns (indices, offsets) with `offsets.len() == cols*k + 1`.
/// Fallible because unpacking verifies lazily-checksummed plane sections.
fn group_by_column(layer: &PackedLayer) -> Result<(Vec<u32>, Vec<usize>)> {
    let (rows, cols, k) = (layer.rows, layer.cols, layer.codebook.len());
    let assigns = layer.try_unpack_assignments()?;
    let mut counts = vec![0usize; cols * k];
    for (idx, &a) in assigns.iter().enumerate() {
        counts[(idx % cols) * k + a as usize] += 1;
    }
    let mut offsets = vec![0usize; cols * k + 1];
    for g in 0..cols * k {
        offsets[g + 1] = offsets[g] + counts[g];
    }
    let mut cursor: Vec<usize> = offsets[..cols * k].to_vec();
    let mut indices = vec![0u32; rows * cols];
    for (idx, &a) in assigns.iter().enumerate() {
        let g = (idx % cols) * k + a as usize;
        indices[cursor[g]] = (idx / cols) as u32;
        cursor[g] += 1;
    }
    Ok((indices, offsets))
}

/// Build the LUT-tier gather path for one layer (the v1 construction).
fn lut_path(layer: &PackedLayer, scheme: &Scheme) -> Result<LutPath> {
    let k = layer.codebook.len();
    let signed = matches!(scheme, Scheme::Binary | Scheme::BinaryScale)
        && k == 2
        && layer.codebook[0] == -layer.codebook[1];
    let (indices, offsets) = group_by_column(layer)?;
    Ok(if signed {
        // keep only each column's positive group (centroid index 1)
        let mut pos = Vec::with_capacity(indices.len() / 2);
        let mut pos_offsets = vec![0usize; layer.cols + 1];
        for j in 0..layer.cols {
            pos.extend_from_slice(&indices[offsets[j * 2 + 1]..offsets[j * 2 + 2]]);
            pos_offsets[j + 1] = pos.len();
        }
        LutPath::Signed { pos, offsets: pos_offsets, scale: layer.codebook[1] }
    } else if matches!(scheme, Scheme::PowersOfTwo { .. }) {
        let mut exps = vec![0i32; k];
        let mut signs = vec![0.0f32; k];
        for (c, &v) in layer.codebook.iter().enumerate() {
            if v != 0.0 {
                exps[c] = ((v.abs().to_bits() >> 23) & 0xff) as i32 - 127;
                signs[c] = if v < 0.0 { -1.0 } else { 1.0 };
            }
        }
        LutPath::Pow2 { indices, offsets, exps, signs }
    } else {
        LutPath::Grouped { indices, offsets }
    })
}

/// Pick the bit-sliced kernel for a layer, if its planes permit one.
/// Purely shape-driven (plane kind + codebook), independent of scheme.
fn bit_path(layer: &PackedLayer) -> Option<BitPath> {
    if layer.bits == 0 {
        return None; // K = 1: constant weight matrix, LUT handles it
    }
    match layer.kind {
        PlaneKind::Sign => Some(BitPath::SignPop { scale: layer.codebook[1] }),
        PlaneKind::SignMask => Some(BitPath::TernaryPop { scale: layer.codebook[2] }),
        PlaneKind::Coded => {
            if layer.bits > bitslice::MAX_CODED_BITS {
                return None; // large K: gather lists amortize better
            }
            match bitslice::pow2_tables(&layer.codebook) {
                Some((exps, signs)) => Some(BitPath::CodedPow2 { exps, signs }),
                None => Some(BitPath::CodedK),
            }
        }
    }
}

impl EngineLayer {
    fn build(
        layer: &PackedLayer,
        act: Activation,
        scheme: &Scheme,
        mode: EngineMode,
    ) -> Result<EngineLayer> {
        let exec = match mode {
            EngineMode::Lut => Exec::Lut(lut_path(layer, scheme)?),
            EngineMode::Auto | EngineMode::BitSliced => match bit_path(layer) {
                Some(path) => Exec::Bit {
                    path,
                    planes: layer.planes().to_vec(),
                    wpc: layer.words_per_column(),
                },
                None => Exec::Lut(lut_path(layer, scheme)?),
            },
        };
        Ok(EngineLayer {
            in_dim: layer.rows,
            out_dim: layer.cols,
            k: layer.codebook.len(),
            bits: layer.bits,
            codebook: layer.codebook.clone(),
            bias: layer.bias.clone(),
            act,
            exec,
        })
    }

    /// One input row → one output row through the LUT gather tier.
    fn lut_row(&self, path: &LutPath, x: &[f32], y: &mut [f32]) {
        match path {
            LutPath::Grouped { indices, offsets } => {
                for j in 0..self.out_dim {
                    let mut acc = self.bias[j];
                    for c in 0..self.k {
                        let v = self.codebook[c];
                        if v == 0.0 {
                            continue;
                        }
                        let g = j * self.k + c;
                        acc += v * vecops::gather_sum(x, &indices[offsets[g]..offsets[g + 1]]);
                    }
                    y[j] = acc;
                }
            }
            LutPath::Signed { pos, offsets, scale } => {
                let total = vecops::sum(x);
                for j in 0..self.out_dim {
                    let s_pos = vecops::gather_sum(x, &pos[offsets[j]..offsets[j + 1]]);
                    y[j] = self.bias[j] + scale * (2.0 * s_pos - total);
                }
            }
            LutPath::Pow2 { indices, offsets, exps, signs } => {
                for j in 0..self.out_dim {
                    let mut acc = self.bias[j];
                    for c in 0..self.k {
                        if signs[c] == 0.0 {
                            continue;
                        }
                        let g = j * self.k + c;
                        let s = vecops::gather_sum(x, &indices[offsets[g]..offsets[g + 1]]);
                        acc += signs[c] * mul_pow2(s, exps[c]);
                    }
                    y[j] = acc;
                }
            }
        }
    }

    /// One layer pass over **pre-staged rows** into a reusable output
    /// buffer (resized in place; no allocation once warm). The band sweep
    /// is one task on the multi-task pool, so concurrent layer passes of
    /// different requests interleave. Fallible: bit-sliced layers verify
    /// their (possibly mmap'd, lazily checksummed) plane sections once
    /// per pass before any band reads them.
    fn forward_rows_into<'a, F>(
        &self,
        m: usize,
        row: &F,
        out: &mut Mat,
        blocks: &mut Vec<f32>,
    ) -> Result<()>
    where
        F: Fn(usize) -> &'a [f32] + Sync,
    {
        let n = self.out_dim;
        out.rows = m;
        out.cols = n;
        out.data.resize(m * n, 0.0);
        // verify plane sections once per layer pass (lazy checksum memo);
        // after this the band closures read plain `&[u64]`
        let (p0, p1): (&[u64], &[u64]) = match &self.exec {
            Exec::Bit { planes, .. } => (
                planes[0].verify()?,
                if planes.len() > 1 { planes[1].verify()? } else { &[] },
            ),
            Exec::Lut(_) => (&[], &[]),
        };
        // the popcount paths share one set of per-row block sums across
        // all output columns; computed up front into pool scratch so band
        // closures allocate nothing
        let n_b = self.in_dim.div_ceil(64);
        let needs_blocks = matches!(
            &self.exec,
            Exec::Bit { path: BitPath::SignPop { .. } | BitPath::TernaryPop { .. }, .. }
        );
        if needs_blocks {
            blocks.resize(m * n_b, 0.0);
            for r in 0..m {
                let x = row(r);
                assert_eq!(x.len(), self.in_dim, "input dim mismatch");
                vecops::block_sums(x, &mut blocks[r * n_b..(r + 1) * n_b]);
            }
        }
        let blocks: &[f32] = blocks;
        let do_rows = |rows: std::ops::Range<usize>, odata: &mut [f32]| {
            for (local, r) in rows.enumerate() {
                let x = row(r);
                assert_eq!(x.len(), self.in_dim, "input dim mismatch");
                let y = &mut odata[local * n..(local + 1) * n];
                match &self.exec {
                    Exec::Lut(path) => self.lut_row(path, x, y),
                    Exec::Bit { path, wpc, .. } => match path {
                        BitPath::SignPop { scale } => {
                            let b = &blocks[r * n_b..][..n_b];
                            bitslice::sign_row(x, b, p0, *wpc, *scale, &self.bias, y);
                        }
                        BitPath::TernaryPop { scale } => {
                            let b = &blocks[r * n_b..][..n_b];
                            bitslice::ternary_row(x, b, p0, p1, *wpc, *scale, &self.bias, y);
                        }
                        BitPath::CodedK => {
                            bitslice::coded_row(x, p0, *wpc, self.bits, &self.codebook, &self.bias, y);
                        }
                        BitPath::CodedPow2 { exps, signs } => {
                            bitslice::pow2_row(x, p0, *wpc, self.bits, exps, signs, &self.bias, y);
                        }
                    },
                }
            }
        };
        if m < 2 || m * self.in_dim * n < PAR_MIN_WORK || num_threads() == 1 {
            do_rows(0..m, &mut out.data);
        } else {
            pool::run_bands(m, n, &mut out.data, do_rows);
        }
        match self.act {
            Activation::Tanh => {
                for v in out.data.iter_mut() {
                    *v = v.tanh();
                }
            }
            Activation::Relu => {
                for v in out.data.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Activation::Linear => {}
        }
        Ok(())
    }
}

/// Reusable buffers for [`LutEngine::forward_into`]: two ping-pong
/// activation matrices plus the bit-sliced tier's per-row block sums, all
/// sized lazily and kept warm across requests so a steady-state serve
/// executor allocates nothing per batch.
pub struct EngineScratch {
    bufs: [Mat; 2],
    blocks: Vec<f32>,
}

impl EngineScratch {
    /// Empty scratch; buffers grow to the largest shapes seen.
    pub fn new() -> EngineScratch {
        EngineScratch { bufs: [Mat::zeros(0, 0), Mat::zeros(0, 0)], blocks: Vec::new() }
    }
}

impl Default for EngineScratch {
    fn default() -> EngineScratch {
        EngineScratch::new()
    }
}

/// The engine: per-layer execution paths (bit-sliced plane kernels and/or
/// LUT gather structures) for one [`PackedModel`], ready for batched
/// forward passes.
pub struct LutEngine {
    layers: Vec<EngineLayer>,
    mode: EngineMode,
}

impl LutEngine {
    /// Build with [`EngineMode::Auto`] dispatch (O(P) per layer worst
    /// case; no dense weights are created). Note: building LUT-tier
    /// layers from an mmap'd model unpacks (and therefore verifies) their
    /// sections; bit-sliced layers stay unverified until first forward.
    pub fn new(model: &PackedModel) -> Result<LutEngine> {
        LutEngine::with_mode(model, EngineMode::Auto)
    }

    /// Build with an explicit execution tier (see [`EngineMode`]).
    pub fn with_mode(model: &PackedModel, mode: EngineMode) -> Result<LutEngine> {
        if model.layers.is_empty() {
            return Err(anyhow!("packed model has no layers"));
        }
        for (l, layer) in model.layers.iter().enumerate() {
            if l + 1 < model.layers.len() && layer.cols != model.layers[l + 1].rows {
                return Err(anyhow!(
                    "layer {l} out dim {} != layer {} in dim {}",
                    layer.cols,
                    l + 1,
                    model.layers[l + 1].rows
                ));
            }
        }
        let n = model.layers.len();
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(l, pl)| {
                let act = if l + 1 == n {
                    Activation::Linear
                } else {
                    model.spec.hidden_activation
                };
                EngineLayer::build(pl, act, &model.scheme, mode)
            })
            .collect::<Result<_>>()?;
        Ok(LutEngine { layers, mode })
    }

    /// The mode this engine was built with.
    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    /// Per-layer execution path labels, in layer order: `"sign-pop"`,
    /// `"ternary-pop"`, `"coded-k"`, `"coded-pow2"` (bit-sliced tier) or
    /// `"lut-grouped"`, `"lut-signed"`, `"lut-pow2"` (gather tier).
    pub fn layer_paths(&self) -> Vec<&'static str> {
        self.layers
            .iter()
            .map(|l| match &l.exec {
                Exec::Lut(LutPath::Grouped { .. }) => "lut-grouped",
                Exec::Lut(LutPath::Signed { .. }) => "lut-signed",
                Exec::Lut(LutPath::Pow2 { .. }) => "lut-pow2",
                Exec::Bit { path, .. } => path.label(),
            })
            .collect()
    }

    /// Input dimension (features per request).
    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    /// Output dimension (logits per request).
    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Batched forward pass: (batch, in_dim) → (batch, out_dim) logits.
    ///
    /// Allocating convenience around [`LutEngine::forward_into`]; hot
    /// callers (the serve executors) hold an [`EngineScratch`] instead.
    /// `Err` means a lazily verified plane section failed its checksum
    /// (corrupt model data), never a transient condition.
    pub fn forward(&self, x: &Mat) -> Result<Mat> {
        let mut scratch = EngineScratch::new();
        Ok(self.forward_into(x, &mut scratch)?.clone())
    }

    /// Batched forward pass into reusable scratch buffers: returns a view
    /// of the logits living inside `scratch`, valid until the next call.
    /// Zero heap allocation once the scratch is warm, so pipelined
    /// executors can run concurrent batches without touching the
    /// allocator.
    pub fn forward_into<'s>(&self, x: &Mat, scratch: &'s mut EngineScratch) -> Result<&'s Mat> {
        assert_eq!(x.cols, self.in_dim(), "input dim mismatch");
        self.forward_rows_into(x.rows, |r| x.row(r), scratch)
    }

    /// Batched forward pass over **pre-staged rows**: row `r` of the batch
    /// is whatever slice `row(r)` returns (each must be `in_dim` long), so
    /// callers holding per-request buffers — the micro-batcher's decoded
    /// jobs, wire payloads deserialized straight off a socket — feed the
    /// engine in place, with no copy into a contiguous batch matrix. Only
    /// the first layer reads the input; everything downstream runs on the
    /// scratch activations exactly like [`LutEngine::forward_into`], and
    /// the result is bit-identical to staging the same rows in a `Mat`.
    pub fn forward_rows_into<'a, 's, F>(
        &self,
        rows: usize,
        row: F,
        scratch: &'s mut EngineScratch,
    ) -> Result<&'s Mat>
    where
        F: Fn(usize) -> &'a [f32] + Sync,
    {
        let EngineScratch { bufs: [a, b], blocks } = scratch;
        self.layers[0].forward_rows_into(rows, &row, a, blocks)?;
        let mut in_a = true;
        for layer in &self.layers[1..] {
            if in_a {
                let m = a.rows;
                layer.forward_rows_into(m, &|r| a.row(r), b, blocks)?;
            } else {
                let m = b.rows;
                layer.forward_rows_into(m, &|r| b.row(r), a, blocks)?;
            }
            in_a = !in_a;
        }
        Ok(if in_a { a } else { b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::MlpSpec;
    use crate::quant::LayerQuantizer;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn packed_net(scheme: &Scheme, sizes: Vec<usize>, seed: u64) -> PackedModel {
        let spec = MlpSpec {
            sizes,
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.6)).collect();
            let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.2)).collect());
        }
        PackedModel::from_parts("net", &spec, scheme, &codebooks, &assignments, &biases).unwrap()
    }

    fn max_logit_dev_mode(model: &PackedModel, batch: usize, seed: u64, mode: EngineMode) -> f32 {
        let engine = LutEngine::with_mode(model, mode).unwrap();
        let net = model.to_mlp();
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(batch, engine.in_dim());
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let got = engine.forward(&x).unwrap();
        let (dense, _) = net.forward(&x, false, None);
        assert_eq!(got.rows, dense.rows);
        assert_eq!(got.cols, dense.cols);
        let mut dev = 0.0f32;
        for (a, b) in got.data.iter().zip(&dense.data) {
            dev = dev.max((a - b).abs());
        }
        dev
    }

    fn max_logit_dev(model: &PackedModel, batch: usize, seed: u64) -> f32 {
        max_logit_dev_mode(model, batch, seed, EngineMode::Auto)
    }

    #[test]
    fn forward_matches_dense_all_schemes_all_modes() {
        let schemes = [
            Scheme::AdaptiveCodebook { k: 4 },
            Scheme::AdaptiveCodebook { k: 16 },
            Scheme::AdaptiveWithZero { k: 5 },
            Scheme::FixedCodebook { codebook: vec![-0.8, -0.2, 0.0, 0.3, 0.9] },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 3 },
        ];
        for mode in [EngineMode::Auto, EngineMode::Lut, EngineMode::BitSliced] {
            for (i, scheme) in schemes.iter().enumerate() {
                let model = packed_net(scheme, vec![15, 10, 6], 200 + i as u64);
                let dev = max_logit_dev_mode(&model, 7, 300 + i as u64, mode);
                assert!(dev <= 1e-3, "{scheme:?} {mode:?}: max logit deviation {dev}");
            }
        }
    }

    #[test]
    fn auto_dispatch_picks_bit_sliced_paths_per_layer() {
        let cases: [(Scheme, &str); 6] = [
            (Scheme::Binary, "sign-pop"),
            (Scheme::BinaryScale, "sign-pop"),
            (Scheme::Ternary, "ternary-pop"),
            (Scheme::TernaryScale, "ternary-pop"),
            (Scheme::PowersOfTwo { c: 3 }, "coded-pow2"),
            (Scheme::AdaptiveCodebook { k: 4 }, "coded-k"),
        ];
        for (scheme, want) in &cases {
            let model = packed_net(scheme, vec![15, 10, 6], 900);
            let engine = LutEngine::new(&model).unwrap();
            assert_eq!(engine.layer_paths(), vec![*want; 2], "{scheme:?}");
            assert_eq!(engine.mode(), EngineMode::Auto);
        }
        // large K has no bit-sliced form: Auto falls back to gathers
        let model = packed_net(&Scheme::AdaptiveCodebook { k: 256 }, vec![15, 10, 6], 901);
        assert_eq!(
            LutEngine::new(&model).unwrap().layer_paths(),
            vec!["lut-grouped"; 2]
        );
        // and BitSliced mode falls back the same way instead of erroring
        let engine = LutEngine::with_mode(&model, EngineMode::BitSliced).unwrap();
        assert_eq!(engine.layer_paths(), vec!["lut-grouped"; 2]);
        // forcing Lut forces gathers even for binary
        let model = packed_net(&Scheme::Binary, vec![15, 10, 6], 902);
        let engine = LutEngine::with_mode(&model, EngineMode::Lut).unwrap();
        assert_eq!(engine.layer_paths(), vec!["lut-signed"; 2]);
    }

    #[test]
    fn engine_mode_names_roundtrip() {
        for mode in [EngineMode::Auto, EngineMode::Lut, EngineMode::BitSliced] {
            assert_eq!(mode.name().parse::<EngineMode>().unwrap(), mode);
            assert_eq!(format!("{mode}"), mode.name());
        }
        assert!("xnor".parse::<EngineMode>().is_err());
        assert_eq!(EngineMode::default(), EngineMode::Auto);
    }

    #[test]
    fn forward_matches_dense_threaded_batch() {
        // first layer: 64·200·180 ≈ 2.3M adds > PAR_MIN_WORK, so this
        // exercises the threaded row split (second layer stays serial) on
        // both tiers
        let model = packed_net(&Scheme::BinaryScale, vec![200, 180, 4], 41);
        for mode in [EngineMode::BitSliced, EngineMode::Lut] {
            let dev = max_logit_dev_mode(&model, 64, 42, mode);
            assert!(dev <= 1e-3, "threaded {mode:?}: {dev}");
        }
    }

    #[test]
    fn lut_forward_property() {
        check("engine == dense", 25, |g| {
            let sizes = vec![g.usize_in(2, 12), g.usize_in(1, 10), g.usize_in(1, 6)];
            let k = g.usize_in(1, 8);
            let model = packed_net(
                &Scheme::AdaptiveCodebook { k },
                sizes,
                500 + g.case as u64,
            );
            let dev = max_logit_dev(&model, g.usize_in(1, 5), 600 + g.case as u64);
            assert!(dev <= 1e-3, "K={k}: {dev}");
        });
    }

    #[test]
    fn mul_pow2_matches_float_multiply() {
        check("mul_pow2", 200, |g| {
            let x = g.f32_in(-100.0, 100.0);
            let e = g.usize_in(0, 12) as i32 - 6;
            let expect = x * 2.0f32.powi(e);
            assert_eq!(mul_pow2(x, e), expect, "x={x} e={e}");
        });
        assert_eq!(mul_pow2(0.0, -3), 0.0);
        assert_eq!(mul_pow2(-8.0, -3), -1.0);
        assert_eq!(mul_pow2(3.0, 0), 3.0);
        // near-overflow falls back without UB
        let big = f32::MAX / 2.0;
        assert!(mul_pow2(big, 2).is_infinite());
        // subnormal input falls back to the multiply
        let tiny = f32::MIN_POSITIVE / 4.0;
        assert_eq!(mul_pow2(tiny, 1), tiny * 2.0);
    }

    #[test]
    fn forward_into_matches_forward_across_batch_shapes() {
        // one scratch recycled across growing and shrinking batches (the
        // pipelined executor's usage pattern) must equal the allocating
        // form bit for bit — on both tiers
        let model = packed_net(&Scheme::AdaptiveCodebook { k: 4 }, vec![12, 9, 5], 71);
        for mode in [EngineMode::Auto, EngineMode::Lut] {
            let engine = LutEngine::with_mode(&model, mode).unwrap();
            let mut scratch = EngineScratch::new();
            let mut rng = Rng::new(72);
            for batch in [3usize, 7, 1, 5] {
                let mut x = Mat::zeros(batch, engine.in_dim());
                rng.fill_normal(&mut x.data, 0.0, 1.0);
                let want = engine.forward(&x).unwrap();
                let got = engine.forward_into(&x, &mut scratch).unwrap();
                assert_eq!(got.rows, want.rows);
                assert_eq!(got.cols, want.cols);
                assert_eq!(got.data, want.data, "batch {batch} {mode:?}");
            }
        }
    }

    #[test]
    fn forward_rows_into_matches_mat_forward_bitwise() {
        // pre-staged rows scattered across separate Vecs (the micro-batch
        // server's job buffers) must produce bit-identical logits to the
        // same rows staged contiguously in a Mat — including across the
        // threaded first-layer band split, on both tiers
        for sizes in [vec![12, 9, 5], vec![200, 180, 4]] {
            for mode in [EngineMode::Auto, EngineMode::Lut] {
                let model = packed_net(&Scheme::TernaryScale, sizes.clone(), 81);
                let engine = LutEngine::with_mode(&model, mode).unwrap();
                let batch = 64usize;
                let mut rng = Rng::new(82);
                let rows: Vec<Vec<f32>> = (0..batch)
                    .map(|_| {
                        let mut r = vec![0.0f32; engine.in_dim()];
                        rng.fill_normal(&mut r, 0.0, 1.0);
                        r
                    })
                    .collect();
                let mut x = Mat::zeros(batch, engine.in_dim());
                for (r, row) in rows.iter().enumerate() {
                    x.row_mut(r).copy_from_slice(row);
                }
                let want = engine.forward(&x).unwrap();
                let mut scratch = EngineScratch::new();
                let got = engine
                    .forward_rows_into(batch, |r| rows[r].as_slice(), &mut scratch)
                    .unwrap();
                assert_eq!(got.rows, want.rows);
                assert_eq!(got.cols, want.cols);
                assert_eq!(got.data, want.data, "{mode:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "input dim mismatch")]
    fn forward_rows_into_rejects_short_rows() {
        let model = packed_net(&Scheme::Binary, vec![6, 4, 2], 9);
        let engine = LutEngine::new(&model).unwrap();
        let bad = vec![0.0f32; 3]; // engine expects 6 features
        let mut scratch = EngineScratch::new();
        let _ = engine.forward_rows_into(1, |_| bad.as_slice(), &mut scratch);
    }

    #[test]
    fn engine_rejects_inconsistent_models() {
        let mut model = packed_net(&Scheme::Binary, vec![6, 4, 2], 9);
        // break the chaining
        model.layers[1].rows = 5;
        assert!(LutEngine::new(&model).is_err());
    }

    #[test]
    fn pruned_centroids_cost_no_groups() {
        // Ternary stores pruned weights as 0-bits in the mask plane (or
        // skipped groups on the LUT tier), so they do proportionally less
        // work on both tiers.
        let model = packed_net(&Scheme::TernaryScale, vec![10, 8, 3], 11);
        let dev = max_logit_dev(&model, 3, 12);
        assert!(dev <= 1e-3, "{dev}");
    }

    #[test]
    fn k1_models_serve_via_lut_fallback() {
        // K = 1 packs to zero planes; Auto must fall back and still match
        let model = packed_net(&Scheme::AdaptiveCodebook { k: 1 }, vec![8, 5, 3], 13);
        let engine = LutEngine::new(&model).unwrap();
        assert_eq!(engine.layer_paths(), vec!["lut-grouped"; 2]);
        let dev = max_logit_dev(&model, 4, 14);
        assert!(dev <= 1e-3, "{dev}");
    }
}
