//! Model registry: many [`PackedModel`]s (e.g. binary / ternary / pow2 /
//! adaptive-K variants of one net) loaded into one process, each with its
//! [`LutEngine`] built once, routed per-request by name. One server can
//! therefore expose a whole compression-tradeoff family and let callers
//! pick their accuracy/latency point.

use super::engine::{EngineMode, LutEngine};
use super::format::EXTENSION;
use super::packed::PackedModel;
use crate::obs::{self, HistId};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// Name and dimensions of one registered model — the per-model entry of
/// the catalog the network plane advertises to connecting clients in the
/// LCQ-RPC hello frame (`docs/wire-protocol.md`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelInfo {
    /// Registry key (the wire format's model id).
    pub name: String,
    /// Features per request row.
    pub in_dim: usize,
    /// Logits per request row.
    pub out_dim: usize,
}

/// A packed model plus its ready-to-serve engine.
pub struct LoadedModel {
    /// The deserialized `.lcq` artifact (kept for metadata/accounting).
    pub packed: PackedModel,
    /// The engine built from it at registration time (bit-sliced and/or
    /// gather tiers per [`EngineMode`]).
    pub engine: LutEngine,
}

/// Name → model map. Cheap to share: handing requests to the server takes
/// an `Arc<Registry>`.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<LoadedModel>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a model under its own name, building its engine with
    /// [`EngineMode::Auto`] dispatch. Replaces any previous model of the
    /// same name.
    pub fn insert(&mut self, packed: PackedModel) -> Result<()> {
        self.insert_with_mode(packed, EngineMode::Auto)
    }

    /// Register a model with an explicit engine execution tier.
    pub fn insert_with_mode(&mut self, packed: PackedModel, mode: EngineMode) -> Result<()> {
        let engine = LutEngine::with_mode(&packed, mode)
            .with_context(|| format!("building engine for '{}'", packed.name))?;
        self.models
            .insert(packed.name.clone(), Arc::new(LoadedModel { packed, engine }));
        Ok(())
    }

    /// Load every `*.lcq` file in a directory with [`EngineMode::Auto`]
    /// engines (see [`Registry::load_dir_with`]).
    pub fn load_dir(dir: &Path) -> Result<Registry> {
        Registry::load_dir_with(dir, EngineMode::Auto)
    }

    /// Load every `*.lcq` file in a directory, **zero-copy**: each file is
    /// memory-mapped ([`PackedModel::load_mmap`]) so its plane sections
    /// are served straight from the page cache and checksum-verified
    /// lazily on first touch, making cold load O(header) per model. Per
    /// model, the open→engine-ready wall time lands in the `model_load`
    /// histogram; `lcq_mmap_loads` counts true mappings (the observability
    /// plane exposes both over the stats wire).
    pub fn load_dir_with(dir: &Path, mode: EngineMode) -> Result<Registry> {
        let mut reg = Registry::new();
        let entries =
            std::fs::read_dir(dir).with_context(|| format!("reading model dir {dir:?}"))?;
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) == Some(EXTENSION) {
                let start = std::time::Instant::now();
                reg.insert_with_mode(PackedModel::load_mmap(&path)?, mode)?;
                if obs::enabled() {
                    obs::hist(HistId::ModelLoad).record_ns(start.elapsed().as_nanos() as u64);
                }
            }
        }
        if reg.is_empty() {
            return Err(anyhow!("no .{EXTENSION} models found in {dir:?}"));
        }
        Ok(reg)
    }

    /// Look up a model (and its engine) by registry name.
    pub fn get(&self, name: &str) -> Option<Arc<LoadedModel>> {
        self.models.get(name).cloned()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Name + dimensions for every registered model, sorted by name — the
    /// model-id catalog the network plane hands to connecting clients, so
    /// they can validate request arity before any bytes hit the engine.
    pub fn catalog(&self) -> Vec<ModelInfo> {
        self.models
            .values()
            .map(|m| ModelInfo {
                name: m.packed.name.clone(),
                in_dim: m.engine.in_dim(),
                out_dim: m.engine.out_dim(),
            })
            .collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Route one batch through a named model.
    pub fn infer(&self, name: &str, x: &crate::linalg::Mat) -> Result<crate::linalg::Mat> {
        let m = self
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not registered (have {:?})", self.names()))?;
        if x.cols != m.engine.in_dim() {
            return Err(anyhow!(
                "model '{name}' expects {} features, got {}",
                m.engine.in_dim(),
                x.cols
            ));
        }
        m.engine.forward(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{Activation, MlpSpec};
    use crate::quant::{LayerQuantizer, Scheme};
    use crate::util::rng::Rng;

    fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
        let spec = MlpSpec {
            sizes: vec![8, 6, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push(vec![0.1f32; spec.sizes[l + 1]]);
        }
        PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
    }

    #[test]
    fn registry_routes_a_model_family() {
        let mut reg = Registry::new();
        reg.insert(toy_packed("binary", &Scheme::Binary, 1)).unwrap();
        reg.insert(toy_packed("ternary", &Scheme::Ternary, 2)).unwrap();
        reg.insert(toy_packed("adaptive4", &Scheme::AdaptiveCodebook { k: 4 }, 3))
            .unwrap();
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.names(), vec!["adaptive4", "binary", "ternary"]);
        // the wire-facing catalog carries name + dims, sorted like names()
        let cat = reg.catalog();
        assert_eq!(
            cat,
            vec![
                ModelInfo { name: "adaptive4".into(), in_dim: 8, out_dim: 3 },
                ModelInfo { name: "binary".into(), in_dim: 8, out_dim: 3 },
                ModelInfo { name: "ternary".into(), in_dim: 8, out_dim: 3 },
            ]
        );

        let mut x = Mat::zeros(2, 8);
        let mut rng = Rng::new(9);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        // each name routes to a *different* net
        let yb = reg.infer("binary", &x).unwrap();
        let yt = reg.infer("ternary", &x).unwrap();
        assert_eq!(yb.rows, 2);
        assert_eq!(yb.cols, 3);
        assert!(yb.data.iter().zip(&yt.data).any(|(a, b)| a != b));
        // unknown model and wrong arity are errors
        assert!(reg.infer("nope", &x).is_err());
        let bad = Mat::zeros(2, 5);
        assert!(reg.infer("binary", &bad).is_err());
    }

    #[test]
    fn load_dir_roundtrip() {
        let dir = std::env::temp_dir().join("lcquant_serve_registry_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (name, scheme) in [
            ("binary", Scheme::Binary),
            ("pow2", Scheme::PowersOfTwo { c: 2 }),
        ] {
            toy_packed(name, &scheme, 5).save(&dir.join(format!("{name}.lcq"))).unwrap();
        }
        // non-model files are ignored
        std::fs::write(dir.join("notes.txt"), "hi").unwrap();
        let reg = Registry::load_dir(&dir).unwrap();
        assert_eq!(reg.names(), vec!["binary", "pow2"]);
        assert!(reg.get("binary").is_some());
        let _ = std::fs::remove_dir_all(&dir);
        // empty dir is an error
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Registry::load_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
