//! Binary on-disk format for [`PackedModel`] (`.lcq` files).
//!
//! ```text
//! magic "LCQP" | version u32 | name | spec | scheme | layers | fnv1a-64
//! ```
//!
//! All integers little-endian. The trailing checksum is FNV-1a 64 over
//! every preceding byte (magic included), so truncation and corruption are
//! both detected at load. The payload is the paper-§5 storage: ⌈log₂K⌉
//! bits per weight plus a K-entry f32 codebook and f32 biases per layer —
//! no dense weights ever touch the disk.
//!
//! The full byte-level specification (field tables, bit-packing rules,
//! reader validation obligations, and the exact size equation) is
//! maintained for third-party implementors in `docs/lcq-format.md`; the
//! tests below pin this file to that document.

use super::packed::{PackedLayer, PackedModel};
use crate::nn::{Activation, MlpSpec};
use crate::quant::ratio::bits_per_weight;
use crate::quant::Scheme;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LCQP";
const VERSION: u32 = 1;

/// File extension used by [`crate::serve::Registry::load_dir`].
pub const EXTENSION: &str = "lcq";

/// FNV-1a 64 — the checksum shared by the `.lcq` file format and the
/// LCQ-RPC wire frames ([`crate::net::proto`]).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---- little-endian writer/reader --------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(anyhow!(
                "truncated model file: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f32()).collect()
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| anyhow!("bad utf8 string: {e}"))
    }
}

// ---- scheme / activation codecs ---------------------------------------

fn write_scheme(w: &mut Writer, s: &Scheme) {
    match s {
        Scheme::AdaptiveCodebook { k } => {
            w.u8(0);
            w.u32(*k as u32);
        }
        Scheme::FixedCodebook { codebook } => {
            w.u8(1);
            w.f32s(codebook);
        }
        Scheme::Binary => w.u8(2),
        Scheme::BinaryScale => w.u8(3),
        Scheme::Ternary => w.u8(4),
        Scheme::TernaryScale => w.u8(5),
        Scheme::PowersOfTwo { c } => {
            w.u8(6);
            w.u32(*c);
        }
        Scheme::AdaptiveWithZero { k } => {
            w.u8(7);
            w.u32(*k as u32);
        }
    }
}

fn read_scheme(r: &mut Reader) -> Result<Scheme> {
    Ok(match r.u8()? {
        0 => Scheme::AdaptiveCodebook { k: r.u32()? as usize },
        1 => Scheme::FixedCodebook { codebook: r.f32s()? },
        2 => Scheme::Binary,
        3 => Scheme::BinaryScale,
        4 => Scheme::Ternary,
        5 => Scheme::TernaryScale,
        6 => Scheme::PowersOfTwo { c: r.u32()? },
        7 => Scheme::AdaptiveWithZero { k: r.u32()? as usize },
        t => return Err(anyhow!("unknown scheme tag {t}")),
    })
}

fn activation_tag(a: Activation) -> u8 {
    match a {
        Activation::Tanh => 0,
        Activation::Relu => 1,
        Activation::Linear => 2,
    }
}

fn activation_from_tag(t: u8) -> Result<Activation> {
    Ok(match t {
        0 => Activation::Tanh,
        1 => Activation::Relu,
        2 => Activation::Linear,
        _ => return Err(anyhow!("unknown activation tag {t}")),
    })
}

impl PackedModel {
    /// Serialize (header + payload + checksum).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::default();
        w.buf.extend_from_slice(MAGIC);
        w.u32(VERSION);
        w.str(&self.name);
        // spec
        w.u32(self.spec.sizes.len() as u32);
        for &s in &self.spec.sizes {
            w.u64(s as u64);
        }
        w.u8(activation_tag(self.spec.hidden_activation));
        w.f32s(&self.spec.dropout_keep);
        write_scheme(&mut w, &self.scheme);
        // layers
        w.u32(self.layers.len() as u32);
        for l in &self.layers {
            w.u64(l.rows as u64);
            w.u64(l.cols as u64);
            w.u32(l.bits as u32);
            w.f32s(&l.codebook);
            w.f32s(&l.bias);
            w.u64(l.packed.len() as u64);
            for &word in &l.packed {
                w.u64(word);
            }
        }
        let checksum = fnv1a(&w.buf);
        w.u64(checksum);
        w.buf
    }

    /// Deserialize and verify magic, version and checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<PackedModel> {
        if bytes.len() < MAGIC.len() + 4 + 8 {
            return Err(anyhow!("model file too short ({} bytes)", bytes.len()));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let computed = fnv1a(body);
        if stored != computed {
            return Err(anyhow!(
                "checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ));
        }
        let mut r = Reader { buf: body, pos: 0 };
        if r.take(4)? != MAGIC {
            return Err(anyhow!("bad magic (not an .lcq packed model)"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(anyhow!("unsupported format version {version} (expected {VERSION})"));
        }
        let name = r.str()?;
        let n_sizes = r.u32()? as usize;
        let sizes: Vec<usize> =
            (0..n_sizes).map(|_| r.u64().map(|v| v as usize)).collect::<Result<_>>()?;
        if sizes.len() < 2 {
            return Err(anyhow!("spec needs >= 2 sizes, got {sizes:?}"));
        }
        let hidden_activation = activation_from_tag(r.u8()?)?;
        let dropout_keep = r.f32s()?;
        let spec = MlpSpec { sizes, hidden_activation, dropout_keep };
        let scheme = read_scheme(&mut r)?;
        let n_layers = r.u32()? as usize;
        if n_layers != spec.n_layers() {
            return Err(anyhow!(
                "layer count {n_layers} does not match spec {}",
                spec.n_layers()
            ));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let bits = r.u32()? as usize;
            let codebook = r.f32s()?;
            let bias = r.f32s()?;
            let n_words = r.u64()? as usize;
            // validate shapes BEFORE any size arithmetic: header integers
            // are attacker-controlled until tied back to the spec, and the
            // contract is Err, not panic/overflow
            if rows != spec.sizes[l] || cols != spec.sizes[l + 1] {
                return Err(anyhow!(
                    "layer {l}: {rows}x{cols} does not match spec {}x{}",
                    spec.sizes[l],
                    spec.sizes[l + 1]
                ));
            }
            if bias.len() != cols || codebook.is_empty() {
                return Err(anyhow!("layer {l}: bad bias/codebook lengths"));
            }
            if bits != bits_per_weight(codebook.len()) {
                return Err(anyhow!(
                    "layer {l}: {bits} bits/weight inconsistent with K={}",
                    codebook.len()
                ));
            }
            let total_bits = rows
                .checked_mul(cols)
                .and_then(|n| n.checked_mul(bits))
                .ok_or_else(|| anyhow!("layer {l}: dimension overflow"))?;
            let expected_words = total_bits.div_ceil(64);
            if n_words != expected_words {
                return Err(anyhow!(
                    "layer {l}: {n_words} packed words, expected {expected_words}"
                ));
            }
            let packed: Vec<u64> = (0..n_words).map(|_| r.u64()).collect::<Result<_>>()?;
            let layer = PackedLayer { rows, cols, bits, codebook, bias, packed };
            let k = layer.codebook.len() as u32;
            if (0..layer.weight_count()).any(|i| layer.assignment(i) >= k) {
                return Err(anyhow!("layer {l}: assignment index out of codebook range"));
            }
            layers.push(layer);
        }
        if r.pos != r.buf.len() {
            return Err(anyhow!("{} trailing bytes after model", r.buf.len() - r.pos));
        }
        Ok(PackedModel { name, spec, scheme, layers })
    }

    /// Write to a file (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
        std::fs::write(path, self.to_bytes()).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<PackedModel> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        PackedModel::from_bytes(&bytes).with_context(|| format!("parsing {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ratio;
    use crate::quant::LayerQuantizer;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn toy_model(scheme: &Scheme, seed: u64) -> PackedModel {
        let spec = MlpSpec {
            sizes: vec![11, 6, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
        }
        PackedModel::from_parts("toy", &spec, scheme, &codebooks, &assignments, &biases).unwrap()
    }

    #[test]
    fn save_load_identity_all_schemes() {
        let schemes = [
            Scheme::AdaptiveCodebook { k: 5 },
            Scheme::AdaptiveWithZero { k: 4 },
            Scheme::FixedCodebook { codebook: vec![-0.5, 0.0, 0.25, 0.75] },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 3 },
        ];
        for (i, scheme) in schemes.iter().enumerate() {
            let m = toy_model(scheme, 40 + i as u64);
            let bytes = m.to_bytes();
            let back = PackedModel::from_bytes(&bytes).unwrap();
            assert_eq!(back, m, "{scheme:?}");
        }
    }

    #[test]
    fn save_load_identity_across_k() {
        check("bytes roundtrip", 12, |g| {
            let k = [2usize, 3, 4, 5, 16, 256][g.case % 6];
            let m = toy_model(&Scheme::AdaptiveCodebook { k }, 60 + g.case as u64);
            assert_eq!(PackedModel::from_bytes(&m.to_bytes()).unwrap(), m, "K={k}");
        });
    }

    #[test]
    fn file_roundtrip_and_size_accounting() {
        let dir = std::env::temp_dir().join("lcquant_serve_format_test");
        let _ = std::fs::remove_dir_all(&dir);
        let m = toy_model(&Scheme::AdaptiveCodebook { k: 4 }, 77);
        let path = dir.join("toy.lcq");
        m.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back, m);
        // on-disk bytes = eq.(14) payload + format overhead (header, name,
        // spec, per-layer framing, word padding, checksum) — the payload
        // dominates and the overhead is small and accountable.
        let file_bytes = std::fs::metadata(&path).unwrap().len() as usize;
        let payload_bytes = m.payload_bits().div_ceil(8);
        assert!(file_bytes >= payload_bytes, "{file_bytes} < {payload_bytes}");
        let overhead = file_bytes - payload_bytes;
        // generous fixed bound: framing is O(layers), not O(weights)
        assert!(overhead < 256, "format overhead {overhead} bytes");
        // and the ratio accounting matches quant::ratio exactly
        let (p1, p0) = m.spec.param_counts();
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 4, m.n_layers()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The size equation documented in `docs/lcq-format.md`, computed
    /// field by field. Any change to the wire format must update both the
    /// document and this function together.
    fn documented_file_size(m: &PackedModel) -> usize {
        let scheme_bytes = match &m.scheme {
            Scheme::Binary | Scheme::BinaryScale | Scheme::Ternary | Scheme::TernaryScale => 1,
            Scheme::AdaptiveCodebook { .. }
            | Scheme::AdaptiveWithZero { .. }
            | Scheme::PowersOfTwo { .. } => 1 + 4,
            Scheme::FixedCodebook { codebook } => 1 + 4 + 4 * codebook.len(),
        };
        let mut total = 4 + 4; // magic + version
        total += 4 + m.name.len(); // name string
        total += 4 + 8 * m.spec.sizes.len() + 1 + 4 + 4 * m.spec.dropout_keep.len(); // spec
        total += scheme_bytes;
        total += 4; // layer count
        for l in &m.layers {
            total += 8 + 8 + 4; // rows, cols, bits
            total += 4 + 4 * l.codebook.len(); // codebook list
            total += 4 + 4 * l.bias.len(); // bias list
            total += 8 + 8 * (l.weight_count() * l.bits).div_ceil(64); // packed words
        }
        total + 8 // checksum
    }

    #[test]
    fn spec_size_equation_matches_written_bytes() {
        // docs/lcq-format.md's size equation must hold byte-exactly for
        // every scheme family and codebook size, and its payload term must
        // agree with quant::ratio (eq. 14) — the cross-check that keeps
        // the written spec, the writer, and the paper accounting in sync.
        let schemes = [
            Scheme::AdaptiveCodebook { k: 2 },
            Scheme::AdaptiveCodebook { k: 5 },
            Scheme::AdaptiveCodebook { k: 256 },
            Scheme::AdaptiveWithZero { k: 4 },
            Scheme::FixedCodebook { codebook: vec![-0.5, 0.0, 0.25, 0.75] },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 3 },
        ];
        for (i, scheme) in schemes.iter().enumerate() {
            let m = toy_model(scheme, 500 + i as u64);
            let bytes = m.to_bytes();
            assert_eq!(
                bytes.len(),
                documented_file_size(&m),
                "{scheme:?}: file size diverged from docs/lcq-format.md"
            );
            // payload term of the equation ⇔ eq. (14) accounting
            let payload: usize = m
                .layers
                .iter()
                .map(|l| {
                    l.weight_count() * l.bits + (l.codebook.len() + l.bias.len()) * ratio::FLOAT_BITS
                })
                .sum();
            assert_eq!(payload, m.payload_bits(), "{scheme:?}");
        }
        // and uniform-K payloads collapse to ratio::quantized_bits exactly
        let m = toy_model(&Scheme::AdaptiveCodebook { k: 16 }, 77);
        let (p1, p0) = m.spec.param_counts();
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 16, m.n_layers()));
    }

    #[test]
    fn corruption_is_detected() {
        let m = toy_model(&Scheme::Ternary, 88);
        let good = m.to_bytes();
        // flip one payload byte
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(PackedModel::from_bytes(&bad).is_err());
        // truncate
        assert!(PackedModel::from_bytes(&good[..good.len() - 3]).is_err());
        // bad magic (re-checksummed so it reaches the magic check)
        let mut nomagic = good.clone();
        nomagic[0] = b'X';
        let n = nomagic.len();
        let sum = fnv1a(&nomagic[..n - 8]);
        nomagic[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = PackedModel::from_bytes(&nomagic).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // empty / tiny input
        assert!(PackedModel::from_bytes(&[]).is_err());
        assert!(PackedModel::from_bytes(b"LCQP").is_err());
    }

    #[test]
    fn version_gate() {
        let m = toy_model(&Scheme::Binary, 99);
        let mut bytes = m.to_bytes();
        bytes[4] = 9; // version LE byte
        let n = bytes.len();
        let sum = fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = PackedModel::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }
}
