//! Binary on-disk format for [`PackedModel`] (`.lcq` files), version 2.
//!
//! ```text
//! header:   magic "LCQP" | version u32 | name | spec | scheme
//!           | layer metadata (incl. per-plane offset/words/fnv) | fnv1a-64
//! padding:  zero bytes to the next 64-byte boundary
//! sections: one 64-byte-aligned section of u64 plane words per plane,
//!           zero padding between sections
//! ```
//!
//! All integers little-endian. Version 2 replaces v1's row-major packed
//! stream + whole-file trailing checksum with **column-major plane
//! sections** (the layouts in [`crate::serve::packed`]) that are 64-byte
//! aligned and **individually FNV-checksummed**: the header carries each
//! section's absolute byte offset, word count and expected checksum, and
//! is itself checksummed. That split is what makes zero-copy loading
//! possible — [`PackedModel::load_mmap`] maps the file, parses and
//! verifies only the header, and serves plane words straight from the
//! page cache; each section's checksum is verified lazily on first touch
//! ([`crate::serve::packed::Words::verify`]). The eager
//! [`PackedModel::from_bytes`] path verifies everything up front and
//! additionally validates plane contents (padding bits zero, codes in
//! codebook range, ternary sign ⊆ mask).
//!
//! The payload is the paper-§5 storage: ⌈log₂K⌉ bits per weight plus a
//! K-entry f32 codebook and f32 biases per layer — no dense weights ever
//! touch the disk. Alignment padding is format overhead, not payload.
//!
//! The full byte-level specification (field tables, plane layouts,
//! alignment and lazy-checksum semantics, reader validation obligations,
//! and the exact size equation) is maintained for third-party
//! implementors in `docs/lcq-format.md`; the tests below pin this file to
//! that document.

use super::packed::{PackedLayer, PackedModel, PlaneKind, Words};
use crate::nn::{Activation, MlpSpec};
use crate::obs::{self, CounterId};
use crate::quant::ratio::bits_per_weight;
use crate::quant::Scheme;
use crate::util::mmap::MmapRegion;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"LCQP";
const VERSION: u32 = 2;
/// Plane sections start on multiples of this (cache-line / word friendly;
/// keeps mmap'd sections castable to `&[u64]`).
const SECTION_ALIGN: usize = 64;

/// File extension used by [`crate::serve::Registry::load_dir`].
pub const EXTENSION: &str = "lcq";

/// FNV-1a 64 — the checksum shared by the `.lcq` file format and the
/// LCQ-RPC wire frames ([`crate::net::proto`]).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn align_up(v: usize, a: usize) -> usize {
    v.div_ceil(a) * a
}

// ---- little-endian writer/reader --------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(anyhow!(
                "truncated model file: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f32()).collect()
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| anyhow!("bad utf8 string: {e}"))
    }
}

// ---- scheme / activation / plane-kind codecs ---------------------------

fn write_scheme(w: &mut Writer, s: &Scheme) {
    match s {
        Scheme::AdaptiveCodebook { k } => {
            w.u8(0);
            w.u32(*k as u32);
        }
        Scheme::FixedCodebook { codebook } => {
            w.u8(1);
            w.f32s(codebook);
        }
        Scheme::Binary => w.u8(2),
        Scheme::BinaryScale => w.u8(3),
        Scheme::Ternary => w.u8(4),
        Scheme::TernaryScale => w.u8(5),
        Scheme::PowersOfTwo { c } => {
            w.u8(6);
            w.u32(*c);
        }
        Scheme::AdaptiveWithZero { k } => {
            w.u8(7);
            w.u32(*k as u32);
        }
    }
}

fn read_scheme(r: &mut Reader) -> Result<Scheme> {
    Ok(match r.u8()? {
        0 => Scheme::AdaptiveCodebook { k: r.u32()? as usize },
        1 => Scheme::FixedCodebook { codebook: r.f32s()? },
        2 => Scheme::Binary,
        3 => Scheme::BinaryScale,
        4 => Scheme::Ternary,
        5 => Scheme::TernaryScale,
        6 => Scheme::PowersOfTwo { c: r.u32()? },
        7 => Scheme::AdaptiveWithZero { k: r.u32()? as usize },
        t => return Err(anyhow!("unknown scheme tag {t}")),
    })
}

fn activation_tag(a: Activation) -> u8 {
    match a {
        Activation::Tanh => 0,
        Activation::Relu => 1,
        Activation::Linear => 2,
    }
}

fn activation_from_tag(t: u8) -> Result<Activation> {
    Ok(match t {
        0 => Activation::Tanh,
        1 => Activation::Relu,
        2 => Activation::Linear,
        _ => return Err(anyhow!("unknown activation tag {t}")),
    })
}

fn kind_tag(k: PlaneKind) -> u8 {
    match k {
        PlaneKind::Coded => 0,
        PlaneKind::Sign => 1,
        PlaneKind::SignMask => 2,
    }
}

fn kind_from_tag(t: u8) -> Result<PlaneKind> {
    Ok(match t {
        0 => PlaneKind::Coded,
        1 => PlaneKind::Sign,
        2 => PlaneKind::SignMask,
        _ => return Err(anyhow!("unknown plane kind tag {t}")),
    })
}

// ---- parsed header ------------------------------------------------------

struct PlaneMeta {
    offset: usize,
    words: usize,
    fnv: u64,
}

struct LayerMeta {
    rows: usize,
    cols: usize,
    bits: usize,
    kind: PlaneKind,
    codebook: Vec<f32>,
    bias: Vec<f32>,
    planes: Vec<PlaneMeta>,
}

struct Header {
    name: String,
    spec: MlpSpec,
    scheme: Scheme,
    layers: Vec<LayerMeta>,
    /// End of the zero-padded header = offset of the first section.
    header_end: usize,
}

impl LayerMeta {
    fn words_per_column(&self) -> usize {
        if self.bits == 0 {
            0
        } else {
            match self.kind {
                PlaneKind::Sign | PlaneKind::SignMask => self.rows.div_ceil(64),
                PlaneKind::Coded => (self.rows * self.bits).div_ceil(64),
            }
        }
    }
}

/// Parse and fully validate the v2 header against `bytes` (the whole
/// file): magic, version, header checksum, shapes vs spec, kind vs
/// codebook shape, plane counts/sizes, and the canonical aligned section
/// layout (each section exactly at the 64-byte alignment of its
/// predecessor's end, the last ending exactly at EOF). Section *contents*
/// are not touched — both the lazy mmap path and the eager path build on
/// this, and the eager path layers its own payload validation on top.
fn parse_header(bytes: &[u8]) -> Result<Header> {
    if bytes.len() < MAGIC.len() + 4 + 8 {
        return Err(anyhow!("model file too short ({} bytes)", bytes.len()));
    }
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(4)? != MAGIC {
        return Err(anyhow!("bad magic (not an .lcq packed model)"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(anyhow!("unsupported format version {version} (expected {VERSION})"));
    }
    let name = r.str()?;
    let n_sizes = r.u32()? as usize;
    let sizes: Vec<usize> =
        (0..n_sizes).map(|_| r.u64().map(|v| v as usize)).collect::<Result<_>>()?;
    if sizes.len() < 2 {
        return Err(anyhow!("spec needs >= 2 sizes, got {sizes:?}"));
    }
    let hidden_activation = activation_from_tag(r.u8()?)?;
    let dropout_keep = r.f32s()?;
    let spec = MlpSpec { sizes, hidden_activation, dropout_keep };
    let scheme = read_scheme(&mut r)?;
    let n_layers = r.u32()? as usize;
    if n_layers != spec.n_layers() {
        return Err(anyhow!("layer count {n_layers} does not match spec {}", spec.n_layers()));
    }
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let bits = r.u32()? as usize;
        let kind = kind_from_tag(r.u8()?)?;
        let codebook = r.f32s()?;
        let bias = r.f32s()?;
        // validate shapes BEFORE any size arithmetic: header integers are
        // attacker-controlled until tied back to the spec, and the
        // contract is Err, not panic/overflow
        if rows != spec.sizes[l] || cols != spec.sizes[l + 1] {
            return Err(anyhow!(
                "layer {l}: {rows}x{cols} does not match spec {}x{}",
                spec.sizes[l],
                spec.sizes[l + 1]
            ));
        }
        if bias.len() != cols || codebook.is_empty() {
            return Err(anyhow!("layer {l}: bad bias/codebook lengths"));
        }
        if bits != bits_per_weight(codebook.len()) {
            return Err(anyhow!(
                "layer {l}: {bits} bits/weight inconsistent with K={}",
                codebook.len()
            ));
        }
        if kind != PlaneKind::for_codebook(&codebook) {
            return Err(anyhow!(
                "layer {l}: plane kind {kind:?} does not match the codebook shape"
            ));
        }
        rows.checked_mul(cols)
            .and_then(|n| n.checked_mul(bits))
            .ok_or_else(|| anyhow!("layer {l}: dimension overflow"))?;
        let n_planes = r.u8()? as usize;
        let expected_planes = if bits == 0 {
            0
        } else if kind == PlaneKind::SignMask {
            2
        } else {
            1
        };
        if n_planes != expected_planes {
            return Err(anyhow!(
                "layer {l}: {n_planes} planes, expected {expected_planes} for {kind:?}"
            ));
        }
        let mut planes = Vec::with_capacity(n_planes);
        for _ in 0..n_planes {
            let offset = r.u64()? as usize;
            let words = r.u64()? as usize;
            let fnv = r.u64()?;
            planes.push(PlaneMeta { offset, words, fnv });
        }
        let meta = LayerMeta { rows, cols, bits, kind, codebook, bias, planes };
        let expected_words = meta.cols * meta.words_per_column();
        if meta.planes.iter().any(|p| p.words != expected_words) {
            return Err(anyhow!(
                "layer {l}: plane word count does not match cols × words/column = {expected_words}"
            ));
        }
        layers.push(meta);
    }
    // header checksum covers every byte before it
    let body_end = r.pos;
    let stored = r.u64()?;
    let computed = fnv1a(&bytes[..body_end]);
    if stored != computed {
        return Err(anyhow!(
            "header checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
        ));
    }
    let header_end = align_up(r.pos, SECTION_ALIGN);
    if bytes.len() < header_end {
        return Err(anyhow!("file ends inside header padding"));
    }
    if bytes[r.pos..header_end].iter().any(|&b| b != 0) {
        return Err(anyhow!("nonzero header padding"));
    }
    // sections must sit exactly at the canonical aligned layout
    let mut cursor = header_end;
    for (l, meta) in layers.iter().enumerate() {
        for (p, plane) in meta.planes.iter().enumerate() {
            cursor = align_up(cursor, SECTION_ALIGN);
            if plane.offset != cursor {
                return Err(anyhow!(
                    "layer {l} plane {p}: section offset {} breaks the canonical layout \
                     (expected {cursor})",
                    plane.offset
                ));
            }
            let len = plane
                .words
                .checked_mul(8)
                .ok_or_else(|| anyhow!("layer {l} plane {p}: section size overflow"))?;
            cursor = cursor
                .checked_add(len)
                .ok_or_else(|| anyhow!("layer {l} plane {p}: section end overflow"))?;
            if cursor > bytes.len() {
                return Err(anyhow!("layer {l} plane {p}: section extends past end of file"));
            }
        }
    }
    if cursor != bytes.len() {
        return Err(anyhow!("{} trailing bytes after the last section", bytes.len() - cursor));
    }
    Ok(Header { name, spec, scheme, layers, header_end })
}

/// Eager payload validation for one parsed layer: per-column padding bits
/// zero, ternary sign ⊆ mask, coded codes inside the codebook.
fn validate_layer_payload(l: usize, layer: &PackedLayer) -> Result<()> {
    if layer.bits == 0 {
        return Ok(());
    }
    let wpc = layer.words_per_column();
    let pad_bits = match layer.kind {
        PlaneKind::Sign | PlaneKind::SignMask => layer.rows % 64,
        PlaneKind::Coded => (layer.rows * layer.bits) % 64,
    };
    if pad_bits != 0 {
        let pad_mask = !((1u64 << pad_bits) - 1);
        for plane in layer.planes() {
            let words = plane.raw();
            for c in 0..layer.cols {
                if words[c * wpc + wpc - 1] & pad_mask != 0 {
                    return Err(anyhow!("layer {l}: nonzero padding bits in column {c}"));
                }
            }
        }
    }
    match layer.kind {
        PlaneKind::SignMask => {
            let sign = layer.planes()[0].raw();
            let mask = layer.planes()[1].raw();
            if sign.iter().zip(mask).any(|(s, m)| s & !m != 0) {
                return Err(anyhow!("layer {l}: sign plane not a subset of the nonzero mask"));
            }
        }
        PlaneKind::Coded => {
            let k = layer.codebook.len() as u32;
            if layer.unpack_assignments().iter().any(|&a| a >= k) {
                return Err(anyhow!("layer {l}: assignment index out of codebook range"));
            }
        }
        PlaneKind::Sign => {}
    }
    Ok(())
}

impl PackedModel {
    /// Serialize: header (with per-section offsets and checksums patched
    /// in on a second pass), zero padding, then the 64-byte-aligned plane
    /// sections.
    pub fn to_bytes(&self) -> Vec<u8> {
        let write_header = |metas: &[Vec<(u64, u64, u64)>]| -> Writer {
            let mut w = Writer::default();
            w.buf.extend_from_slice(MAGIC);
            w.u32(VERSION);
            w.str(&self.name);
            w.u32(self.spec.sizes.len() as u32);
            for &s in &self.spec.sizes {
                w.u64(s as u64);
            }
            w.u8(activation_tag(self.spec.hidden_activation));
            w.f32s(&self.spec.dropout_keep);
            write_scheme(&mut w, &self.scheme);
            w.u32(self.layers.len() as u32);
            for (l, layer) in self.layers.iter().enumerate() {
                w.u64(layer.rows as u64);
                w.u64(layer.cols as u64);
                w.u32(layer.bits as u32);
                w.u8(kind_tag(layer.kind));
                w.f32s(&layer.codebook);
                w.f32s(&layer.bias);
                w.u8(layer.n_planes() as u8);
                for &(off, words, fnv) in &metas[l] {
                    w.u64(off);
                    w.u64(words);
                    w.u64(fnv);
                }
            }
            w
        };
        // pass 1: placeholder metas fix the header length (offsets are
        // fixed-width), which fixes every section offset
        let placeholder: Vec<Vec<(u64, u64, u64)>> =
            self.layers.iter().map(|l| vec![(0, 0, 0); l.n_planes()]).collect();
        let header_len = write_header(&placeholder).buf.len() + 8; // + header fnv
        let header_end = align_up(header_len, SECTION_ALIGN);
        // lay out sections, serializing each plane's words LE
        let mut cursor = header_end;
        let mut metas: Vec<Vec<(u64, u64, u64)>> = Vec::with_capacity(self.layers.len());
        let mut sections: Vec<(usize, Vec<u8>)> = Vec::new();
        for layer in &self.layers {
            let mut lm = Vec::with_capacity(layer.n_planes());
            for plane in layer.planes() {
                cursor = align_up(cursor, SECTION_ALIGN);
                let words = plane.raw();
                let mut bytes = Vec::with_capacity(words.len() * 8);
                for &word in words {
                    bytes.extend_from_slice(&word.to_le_bytes());
                }
                lm.push((cursor as u64, words.len() as u64, fnv1a(&bytes)));
                let start = cursor;
                cursor += bytes.len();
                sections.push((start, bytes));
            }
            metas.push(lm);
        }
        // pass 2: real header + checksum + padding + sections
        let mut w = write_header(&metas);
        let sum = fnv1a(&w.buf);
        w.u64(sum);
        debug_assert_eq!(w.buf.len(), header_len);
        let mut buf = w.buf;
        for (start, bytes) in sections {
            buf.resize(start, 0);
            buf.extend_from_slice(&bytes);
        }
        buf.resize(buf.len().max(header_end), 0); // plane-less models still pad
        buf
    }

    /// Deserialize **eagerly**: parse + verify the header, verify every
    /// section checksum, materialize owned plane words, and validate the
    /// payload (padding bits zero, sign ⊆ mask, codes in range). The
    /// strict counterpart of [`PackedModel::load_mmap`].
    pub fn from_bytes(bytes: &[u8]) -> Result<PackedModel> {
        let header = parse_header(bytes)?;
        // inter-section padding must be zero (canonical writer output)
        let mut cursor = header.header_end;
        for meta in &header.layers {
            for plane in &meta.planes {
                if bytes[cursor..plane.offset].iter().any(|&b| b != 0) {
                    return Err(anyhow!("nonzero inter-section padding"));
                }
                cursor = plane.offset + plane.words * 8;
            }
        }
        let mut layers = Vec::with_capacity(header.layers.len());
        for (l, meta) in header.layers.iter().enumerate() {
            let mut planes = Vec::with_capacity(meta.planes.len());
            for (p, pm) in meta.planes.iter().enumerate() {
                let section = &bytes[pm.offset..pm.offset + pm.words * 8];
                let computed = fnv1a(section);
                if computed != pm.fnv {
                    return Err(anyhow!(
                        "layer {l} plane {p}: section checksum mismatch \
                         (stored {:#018x}, computed {computed:#018x})",
                        pm.fnv
                    ));
                }
                let words: Vec<u64> = section
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                planes.push(Words::owned(words));
            }
            let layer = PackedLayer {
                rows: meta.rows,
                cols: meta.cols,
                bits: meta.bits,
                kind: meta.kind,
                codebook: meta.codebook.clone(),
                bias: meta.bias.clone(),
                planes,
            };
            validate_layer_payload(l, &layer)?;
            layers.push(layer);
        }
        Ok(PackedModel { name: header.name, spec: header.spec, scheme: header.scheme, layers })
    }

    /// Write to a file (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
        std::fs::write(path, self.to_bytes()).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }

    /// Read from a file, eagerly verified ([`PackedModel::from_bytes`]).
    pub fn load(path: &Path) -> Result<PackedModel> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        PackedModel::from_bytes(&bytes).with_context(|| format!("parsing {path:?}"))
    }

    /// Map a `.lcq` file and serve its plane sections **zero-copy** from
    /// the page cache: only the header is parsed, checksum-verified and
    /// copied; plane words stay in the mapping and each section's FNV is
    /// verified lazily on first touch ([`crate::serve::packed::Words`]).
    /// Cold-load cost is therefore O(header), not O(file).
    ///
    /// Plane *contents* are not pre-validated on this path — the serve
    /// kernels are written to be safe under arbitrary section bytes (bit
    /// planes mask to the row-covering bits; coded accumulators are sized
    /// to 2^bits) — while a checksum mismatch surfaces as an error from
    /// the first forward pass that touches the section.
    ///
    /// On big-endian targets (where the mapped bytes can't be viewed as
    /// words) and when mapping itself is unavailable, this transparently
    /// degrades: the heap-backed region still avoids re-parsing, or the
    /// eager loader takes over entirely. `lcq_mmap_loads` counts only true
    /// page-cache mappings.
    pub fn load_mmap(path: &Path) -> Result<PackedModel> {
        if cfg!(target_endian = "big") {
            return PackedModel::load(path);
        }
        let region = Arc::new(
            MmapRegion::map_file(path).with_context(|| format!("mapping {path:?}"))?,
        );
        let header =
            parse_header(region.bytes()).with_context(|| format!("parsing {path:?}"))?;
        if region.is_mapped() && obs::enabled() {
            obs::counter(CounterId::LcqMmapLoads).inc();
        }
        let mut layers = Vec::with_capacity(header.layers.len());
        for meta in &header.layers {
            let planes = meta
                .planes
                .iter()
                .map(|pm| Words::mapped(Arc::clone(&region), pm.offset, pm.words, pm.fnv))
                .collect();
            layers.push(PackedLayer {
                rows: meta.rows,
                cols: meta.cols,
                bits: meta.bits,
                kind: meta.kind,
                codebook: meta.codebook.clone(),
                bias: meta.bias.clone(),
                planes,
            });
        }
        Ok(PackedModel { name: header.name, spec: header.spec, scheme: header.scheme, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ratio;
    use crate::quant::LayerQuantizer;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn toy_model(scheme: &Scheme, seed: u64) -> PackedModel {
        let spec = MlpSpec {
            sizes: vec![11, 6, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut rng = Rng::new(seed);
        let mut codebooks = Vec::new();
        let mut assignments = Vec::new();
        let mut biases = Vec::new();
        for l in 0..spec.n_layers() {
            let n = spec.sizes[l] * spec.sizes[l + 1];
            let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
            let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
            codebooks.push(out.codebook);
            assignments.push(out.assignments);
            biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
        }
        PackedModel::from_parts("toy", &spec, scheme, &codebooks, &assignments, &biases).unwrap()
    }

    #[test]
    fn save_load_identity_all_schemes() {
        let schemes = [
            Scheme::AdaptiveCodebook { k: 5 },
            Scheme::AdaptiveWithZero { k: 4 },
            Scheme::FixedCodebook { codebook: vec![-0.5, 0.0, 0.25, 0.75] },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 3 },
        ];
        for (i, scheme) in schemes.iter().enumerate() {
            let m = toy_model(scheme, 40 + i as u64);
            let bytes = m.to_bytes();
            let back = PackedModel::from_bytes(&bytes).unwrap();
            assert_eq!(back, m, "{scheme:?}");
        }
    }

    #[test]
    fn save_load_identity_across_k() {
        check("bytes roundtrip", 12, |g| {
            let k = [2usize, 3, 4, 5, 16, 256][g.case % 6];
            let m = toy_model(&Scheme::AdaptiveCodebook { k }, 60 + g.case as u64);
            assert_eq!(PackedModel::from_bytes(&m.to_bytes()).unwrap(), m, "K={k}");
        });
    }

    #[test]
    fn file_roundtrip_and_size_accounting() {
        let dir = std::env::temp_dir().join("lcquant_serve_format_test");
        let _ = std::fs::remove_dir_all(&dir);
        let m = toy_model(&Scheme::AdaptiveCodebook { k: 4 }, 77);
        let path = dir.join("toy.lcq");
        m.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back, m);
        // on-disk bytes = eq.(14) payload + format overhead (header, name,
        // spec, per-layer framing + plane tables, section alignment,
        // per-column word padding) — the payload dominates and the
        // overhead is small and accountable:
        //   header + header padding      < 256 + Σ 24·planes
        //   section alignment            ≤ 63 per plane
        //   column padding               < 8 bytes per column per plane
        let file_bytes = std::fs::metadata(&path).unwrap().len() as usize;
        let payload_bytes = m.payload_bits().div_ceil(8);
        assert!(file_bytes >= payload_bytes, "{file_bytes} < {payload_bytes}");
        let overhead = file_bytes - payload_bytes;
        let n_planes: usize = m.layers.iter().map(|l| l.n_planes()).sum();
        let col_slots: usize =
            m.layers.iter().map(|l| l.cols * l.n_planes()).sum();
        let bound = 256 + 88 * n_planes + 8 * col_slots;
        assert!(overhead < bound, "format overhead {overhead} ≥ bound {bound}");
        // and the ratio accounting matches quant::ratio exactly
        let (p1, p0) = m.spec.param_counts();
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 4, m.n_layers()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The size equation documented in `docs/lcq-format.md`, computed
    /// field by field. Any change to the wire format must update both the
    /// document and this function together.
    fn documented_file_size(m: &PackedModel) -> usize {
        let scheme_bytes = match &m.scheme {
            Scheme::Binary | Scheme::BinaryScale | Scheme::Ternary | Scheme::TernaryScale => 1,
            Scheme::AdaptiveCodebook { .. }
            | Scheme::AdaptiveWithZero { .. }
            | Scheme::PowersOfTwo { .. } => 1 + 4,
            Scheme::FixedCodebook { codebook } => 1 + 4 + 4 * codebook.len(),
        };
        let mut header = 4 + 4; // magic + version
        header += 4 + m.name.len(); // name string
        header += 4 + 8 * m.spec.sizes.len() + 1 + 4 + 4 * m.spec.dropout_keep.len(); // spec
        header += scheme_bytes;
        header += 4; // layer count
        for l in &m.layers {
            header += 8 + 8 + 4 + 1; // rows, cols, bits, kind
            header += 4 + 4 * l.codebook.len(); // codebook list
            header += 4 + 4 * l.bias.len(); // bias list
            header += 1 + 24 * l.n_planes(); // plane count + plane table
        }
        header += 8; // header checksum
        // sections: 64-byte-aligned, words/column × cols words each
        let mut cursor = header.div_ceil(64) * 64;
        for l in &m.layers {
            for _ in 0..l.n_planes() {
                cursor = cursor.div_ceil(64) * 64;
                cursor += 8 * l.cols * l.words_per_column();
            }
        }
        cursor
    }

    #[test]
    fn spec_size_equation_matches_written_bytes() {
        // docs/lcq-format.md's size equation must hold byte-exactly for
        // every scheme family and codebook size, and its payload term must
        // agree with quant::ratio (eq. 14) — the cross-check that keeps
        // the written spec, the writer, and the paper accounting in sync.
        let schemes = [
            Scheme::AdaptiveCodebook { k: 2 },
            Scheme::AdaptiveCodebook { k: 5 },
            Scheme::AdaptiveCodebook { k: 256 },
            Scheme::AdaptiveWithZero { k: 4 },
            Scheme::FixedCodebook { codebook: vec![-0.5, 0.0, 0.25, 0.75] },
            Scheme::Binary,
            Scheme::BinaryScale,
            Scheme::Ternary,
            Scheme::TernaryScale,
            Scheme::PowersOfTwo { c: 3 },
        ];
        for (i, scheme) in schemes.iter().enumerate() {
            let m = toy_model(scheme, 500 + i as u64);
            let bytes = m.to_bytes();
            assert_eq!(
                bytes.len(),
                documented_file_size(&m),
                "{scheme:?}: file size diverged from docs/lcq-format.md"
            );
            // payload term of the equation ⇔ eq. (14) accounting
            let payload: usize = m
                .layers
                .iter()
                .map(|l| {
                    l.weight_count() * l.bits + (l.codebook.len() + l.bias.len()) * ratio::FLOAT_BITS
                })
                .sum();
            assert_eq!(payload, m.payload_bits(), "{scheme:?}");
        }
        // and uniform-K payloads collapse to ratio::quantized_bits exactly
        let m = toy_model(&Scheme::AdaptiveCodebook { k: 16 }, 77);
        let (p1, p0) = m.spec.param_counts();
        assert_eq!(m.payload_bits(), ratio::quantized_bits(p1, p0, 16, m.n_layers()));
    }

    #[test]
    fn corruption_is_detected() {
        let m = toy_model(&Scheme::Ternary, 88);
        let good = m.to_bytes();
        // flip one byte in the last section (eager load: section checksum)
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 3] ^= 0x40;
        let err = PackedModel::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // flip one header byte (model name): header checksum
        let mut bad = good.clone();
        bad[12] ^= 0x20;
        let err = PackedModel::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("magic"), "{err}");
        // truncate: the last section no longer fits
        assert!(PackedModel::from_bytes(&good[..good.len() - 3]).is_err());
        // bad magic
        let mut nomagic = good.clone();
        nomagic[0] = b'X';
        let err = PackedModel::from_bytes(&nomagic).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // trailing garbage
        let mut long = good.clone();
        long.extend_from_slice(&[0u8; 64]);
        assert!(PackedModel::from_bytes(&long).is_err());
        // empty / tiny input
        assert!(PackedModel::from_bytes(&[]).is_err());
        assert!(PackedModel::from_bytes(b"LCQP").is_err());
    }

    #[test]
    fn version_gate() {
        let m = toy_model(&Scheme::Binary, 99);
        let mut bytes = m.to_bytes();
        bytes[4] = 9; // version LE byte
        let err = PackedModel::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn sections_are_aligned_and_planes_word_counted() {
        for scheme in [Scheme::Binary, Scheme::Ternary, Scheme::AdaptiveCodebook { k: 4 }] {
            let m = toy_model(&scheme, 123);
            let bytes = m.to_bytes();
            let header = parse_header(&bytes).unwrap();
            for (meta, layer) in header.layers.iter().zip(&m.layers) {
                assert_eq!(meta.planes.len(), layer.n_planes());
                for pm in &meta.planes {
                    assert_eq!(pm.offset % SECTION_ALIGN, 0, "{scheme:?}");
                    assert_eq!(pm.words, layer.cols * layer.words_per_column());
                }
            }
        }
    }

    #[test]
    fn mmap_load_is_identical_and_lazily_verified() {
        let dir = std::env::temp_dir().join("lcquant_format_mmap_test");
        let _ = std::fs::remove_dir_all(&dir);
        for (i, scheme) in [
            Scheme::Binary,
            Scheme::TernaryScale,
            Scheme::AdaptiveCodebook { k: 4 },
            Scheme::PowersOfTwo { c: 3 },
            Scheme::AdaptiveCodebook { k: 1 },
        ]
        .iter()
        .enumerate()
        {
            let m = toy_model(scheme, 200 + i as u64);
            let path = dir.join(format!("m{i}.lcq"));
            m.save(&path).unwrap();
            let mapped = PackedModel::load_mmap(&path).unwrap();
            // metadata identical, planes verify clean, contents identical
            assert_eq!(mapped.name, m.name);
            assert_eq!(mapped.spec, m.spec);
            assert_eq!(mapped.scheme, m.scheme);
            for (lm, le) in mapped.layers.iter().zip(&m.layers) {
                for p in 0..lm.n_planes() {
                    assert_eq!(lm.plane_words(p).unwrap(), le.planes()[p].raw());
                }
                assert_eq!(
                    lm.try_unpack_assignments().unwrap(),
                    le.unpack_assignments(),
                    "{scheme:?}"
                );
            }
            assert_eq!(mapped, m, "{scheme:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_section_is_rejected_lazily_not_at_load() {
        let dir = std::env::temp_dir().join("lcquant_format_lazy_corrupt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let m = toy_model(&Scheme::Binary, 321);
        let mut bytes = m.to_bytes();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01; // inside the last plane section
        let path = dir.join("corrupt.lcq");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, &bytes).unwrap();
        // eager load rejects immediately…
        assert!(PackedModel::load(&path).is_err());
        // …the lazy path loads fine (header is intact)…
        let mapped = PackedModel::load_mmap(&path).unwrap();
        // …and the corruption surfaces on first verified touch of the
        // damaged plane, stickily
        let last = mapped.layers.last().unwrap();
        let p = last.n_planes() - 1;
        assert!(last.plane_words(p).is_err());
        assert!(last.plane_words(p).is_err());
        assert!(last.try_unpack_assignments().is_err());
        // undamaged layers keep verifying clean
        assert!(mapped.layers[0].plane_words(0).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
