//! Bit-sliced serve kernels: forward passes computed **directly on the
//! packed `u64` plane words** of a [`crate::serve::packed::PackedLayer`]
//! — no dense f32 weights, no per-weight index gathers, no unpacking.
//!
//! Where the LUT tier ([`crate::serve::LutEngine`]'s gather paths) turns
//! the paper-§2.1 identity into *per-centroid index gathers* built at
//! load time, the bit-sliced tier reads the storage representation
//! itself: each output column is a run of `words_per_column` contiguous
//! `u64` words, and the per-centroid partial sums fall out of popcount-
//! style masked reductions over those words
//! ([`crate::linalg::vecops::masked_sum_pc`] and friends, each pinned to
//! a scalar reference decomposition by property tests). The win is
//! memory traffic: a binary 300×100 layer is read as ~4.7 KB of sign
//! plane instead of a 120 KB `u32` gather list — the whole working set of
//! LeNet300-class models fits in L1/L2, and with
//! [`crate::serve::PackedModel::load_mmap`] those words are served
//! zero-copy out of the page cache.
//!
//! Four row kernels, one per representable plane shape:
//!
//! * [`sign_row`] — binary codebooks `{−a, +a}` ([`PlaneKind::Sign`]):
//!   `y_j = b_j + a·(2·S⁺_j − T)` with `S⁺_j` a masked block-compensated
//!   sum over column `j`'s sign words and `T = Σ x_i` shared by every
//!   column.
//! * [`ternary_row`] — ternary codebooks `{−a, 0, +a}`
//!   ([`PlaneKind::SignMask`]): two planes (sign, nonzero mask) give
//!   `y_j = b_j + a·(S⁺_j − S⁻_j)`; pruned weights are 0-bits in the mask
//!   and cost nothing.
//! * [`coded_row`] — general small-K codebooks ([`PlaneKind::Coded`],
//!   `bits ≤ `[`MAX_CODED_BITS`]): a gather-free K-accumulator —
//!   [`crate::linalg::vecops::code_accumulate`] streams the column's
//!   packed codes once, binning `x_i` into `acc[code_i]`, then a K-entry
//!   combine multiplies each bin by its centroid.
//! * [`pow2_row`] — coded layers whose codebook is `{0, ±2^e}`
//!   (`PowersOfTwo`): same accumulator, but the combine shifts each bin's
//!   f32 exponent ([`crate::serve::engine::mul_pow2`]) and applies signs
//!   by add/subtract — no float multiplies at all.
//!
//! # Hostile-input safety
//!
//! These kernels are the first consumers of **lazily verified** plane
//! words (mmap'd sections are checksummed on first touch, not at load),
//! so they must be memory-safe under arbitrary bit patterns even though
//! the checksum will reject them: the popcount kernels mask every word to
//! its row-covering bits, and the coded accumulators are sized `2^bits`
//! (≥ K), so out-of-range codes land in bins the combine never reads.
//!
//! [`PlaneKind::Sign`]: crate::serve::packed::PlaneKind::Sign
//! [`PlaneKind::SignMask`]: crate::serve::packed::PlaneKind::SignMask
//! [`PlaneKind::Coded`]: crate::serve::packed::PlaneKind::Coded

use super::engine::mul_pow2;
use crate::linalg::vecops;

/// Largest `bits` (= ⌈log₂K⌉) the coded kernels accept: the per-row
/// accumulator is a fixed `[f32; 64]` on the stack, zeroed only up to
/// `2^bits` per column. K ≤ 64 covers every small-codebook scheme worth
/// bit-slicing (including `PowersOfTwo` up to c = 31); larger codebooks
/// fall back to the LUT tier, whose gather cost is amortized at that K.
pub const MAX_CODED_BITS: usize = 6;

/// Which bit-sliced kernel a layer dispatches to, chosen once at engine
/// build from the layer's [`crate::serve::packed::PlaneKind`] and
/// codebook shape (see `LutEngine`'s auto-dispatch table).
#[derive(Debug, Clone, PartialEq)]
pub enum BitPath {
    /// [`sign_row`] over the single sign plane.
    SignPop {
        /// The binary magnitude `a` (`codebook == [-a, +a]`).
        scale: f32,
    },
    /// [`ternary_row`] over the (sign, mask) plane pair.
    TernaryPop {
        /// The ternary magnitude `a` (`codebook == [-a, 0, +a]`).
        scale: f32,
    },
    /// [`coded_row`]: gather-free K-accumulator + codebook combine.
    CodedK,
    /// [`pow2_row`]: K-accumulator + exponent-shift combine.
    CodedPow2 {
        /// Per-centroid exponent `e` with `|codebook[c]| = 2^e`
        /// (unused when `signs[c] == 0`).
        exps: Vec<i32>,
        /// Per-centroid sign: `-1.0`, `0.0` (zero centroid) or `+1.0`.
        signs: Vec<f32>,
    },
}

impl BitPath {
    /// Stable label for dispatch introspection (`LutEngine::layer_paths`).
    pub fn label(&self) -> &'static str {
        match self {
            BitPath::SignPop { .. } => "sign-pop",
            BitPath::TernaryPop { .. } => "ternary-pop",
            BitPath::CodedK => "coded-k",
            BitPath::CodedPow2 { .. } => "coded-pow2",
        }
    }
}

/// If `codebook` is exactly `{0, ±2^e}`-shaped (every entry zero or a
/// normal power of two), return the `(exps, signs)` tables for
/// [`pow2_row`]; otherwise `None`. Shape-driven, like
/// [`crate::serve::packed::PlaneKind::for_codebook`]: any scheme that
/// happens to land on a pow2 codebook gets the multiply-free combine.
pub fn pow2_tables(codebook: &[f32]) -> Option<(Vec<i32>, Vec<f32>)> {
    let mut exps = vec![0i32; codebook.len()];
    let mut signs = vec![0.0f32; codebook.len()];
    for (c, &v) in codebook.iter().enumerate() {
        if v == 0.0 {
            continue;
        }
        let bits = v.abs().to_bits();
        let exp = ((bits >> 23) & 0xff) as i32;
        // normal power of two: zero mantissa, exponent in the normal range
        if bits & 0x007f_ffff != 0 || exp == 0 || exp == 0xff {
            return None;
        }
        exps[c] = exp - 127;
        signs[c] = if v < 0.0 { -1.0 } else { 1.0 };
    }
    Some((exps, signs))
}

/// Binary row kernel: `y[j] = bias[j] + scale·(2·S⁺_j − T)` where
/// `S⁺_j` sums `x` over the set bits of column `j`'s sign words and
/// `T = Σ x` (computed once per row). `plane` is the sign plane,
/// `blocks` this row's precomputed 64-element block sums
/// ([`vecops::block_sums`]) — shared across all output columns so the
/// complement branch of the masked sum never re-reads `x`.
pub fn sign_row(
    x: &[f32],
    blocks: &[f32],
    plane: &[u64],
    wpc: usize,
    scale: f32,
    bias: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(plane.len(), y.len() * wpc);
    let total = vecops::sum(x);
    for (j, out) in y.iter_mut().enumerate() {
        let s_pos = vecops::masked_sum_pc(x, &plane[j * wpc..][..wpc], blocks);
        *out = bias[j] + scale * (2.0 * s_pos - total);
    }
}

/// Ternary row kernel: `y[j] = bias[j] + scale·(S⁺_j − S⁻_j)` from the
/// (sign, mask) plane pair; weights outside the mask (the 0 centroid —
/// pruned weights) contribute nothing and cost nothing.
pub fn ternary_row(
    x: &[f32],
    blocks: &[f32],
    sign: &[u64],
    mask: &[u64],
    wpc: usize,
    scale: f32,
    bias: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(sign.len(), y.len() * wpc);
    debug_assert_eq!(mask.len(), y.len() * wpc);
    for (j, out) in y.iter_mut().enumerate() {
        let col = j * wpc..(j + 1) * wpc;
        let (pos, neg) = vecops::ternary_sums(x, &sign[col.clone()], &mask[col], blocks);
        *out = bias[j] + scale * (pos - neg);
    }
}

/// Coded row kernel: per column, stream the packed codes once binning
/// `x_i` into `acc[code_i]` ([`vecops::code_accumulate`]), then combine
/// `y[j] = bias[j] + Σ_c codebook[c]·acc[c]` (zero centroids skipped).
/// K multiplies per output unit, zero gather indices.
pub fn coded_row(
    x: &[f32],
    codes: &[u64],
    wpc: usize,
    bits: usize,
    codebook: &[f32],
    bias: &[f32],
    y: &mut [f32],
) {
    debug_assert!(bits >= 1 && bits <= MAX_CODED_BITS);
    debug_assert_eq!(codes.len(), y.len() * wpc);
    let mut acc = [0.0f32; 1 << MAX_CODED_BITS];
    let slots = 1usize << bits; // ≥ K: hostile codes land in unread bins
    for (j, out) in y.iter_mut().enumerate() {
        let a = &mut acc[..slots];
        a.fill(0.0);
        vecops::code_accumulate(x, &codes[j * wpc..][..wpc], bits as u32, a);
        let mut s = bias[j];
        for (c, &v) in codebook.iter().enumerate() {
            if v != 0.0 {
                s += v * a[c];
            }
        }
        *out = s;
    }
}

/// Power-of-two row kernel: like [`coded_row`], but each bin's combine is
/// an exponent shift ([`mul_pow2`]) applied by add/subtract — the layer
/// pass performs no float multiplies at all (§5's hardware argument for
/// power-of-two codebooks, taken to its end).
pub fn pow2_row(
    x: &[f32],
    codes: &[u64],
    wpc: usize,
    bits: usize,
    exps: &[i32],
    signs: &[f32],
    bias: &[f32],
    y: &mut [f32],
) {
    debug_assert!(bits >= 1 && bits <= MAX_CODED_BITS);
    debug_assert_eq!(codes.len(), y.len() * wpc);
    let mut acc = [0.0f32; 1 << MAX_CODED_BITS];
    let slots = 1usize << bits;
    for (j, out) in y.iter_mut().enumerate() {
        let a = &mut acc[..slots];
        a.fill(0.0);
        vecops::code_accumulate(x, &codes[j * wpc..][..wpc], bits as u32, a);
        let mut s = bias[j];
        for (c, (&e, &sg)) in exps.iter().zip(signs).enumerate() {
            if sg > 0.0 {
                s += mul_pow2(a[c], e);
            } else if sg < 0.0 {
                s -= mul_pow2(a[c], e);
            }
        }
        *out = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::scalar;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn pow2_tables_accept_exact_pow2_codebooks_only() {
        // the PowersOfTwo scheme shape: {0, ±2^-i}
        let (exps, signs) = pow2_tables(&[-1.0, -0.5, -0.25, 0.0, 0.25, 0.5, 1.0]).unwrap();
        assert_eq!(exps, vec![0, -1, -2, 0, -2, -1, 0]);
        assert_eq!(signs, vec![-1.0, -1.0, -1.0, 0.0, 1.0, 1.0, 1.0]);
        // binary-style {−a, +a} with pow2 magnitude also qualifies
        assert!(pow2_tables(&[-2.0, 2.0]).is_some());
        // non-pow2 magnitudes, subnormals and non-finite entries do not
        assert!(pow2_tables(&[-0.3, 0.3]).is_none());
        assert!(pow2_tables(&[0.75]).is_none());
        assert!(pow2_tables(&[f32::MIN_POSITIVE / 2.0]).is_none());
        assert!(pow2_tables(&[f32::INFINITY]).is_none());
        assert!(pow2_tables(&[f32::NAN]).is_none());
        // all-zero degenerates fine
        assert_eq!(pow2_tables(&[0.0]).unwrap().1, vec![0.0]);
    }

    /// The row kernels must be *exactly* the scalar-reference
    /// decomposition composed per column — this is the contract that lets
    /// `tests/bitslice.rs` pin the whole engine to `vecops::scalar`.
    #[test]
    fn row_kernels_match_scalar_reference_composition_bitwise() {
        check("bitslice rows == scalar composition", 40, |g| {
            let rows = g.usize_in(1, 130);
            let cols = g.usize_in(1, 6);
            let wpc = rows.div_ceil(64);
            let mut rng = Rng::new(2000 + g.case as u64);
            let mut x = vec![0.0f32; rows];
            rng.fill_normal(&mut x, 0.0, 1.0);
            let mut blocks = vec![0.0f32; wpc];
            scalar::block_sums(&x, &mut blocks);
            let bias: Vec<f32> = (0..cols).map(|_| rng.normal(0.0, 0.2)).collect();
            let plane: Vec<u64> = (0..cols * wpc)
                .map(|i| {
                    let m = if i % 64 >= 63 { !0 } else { (1u64 << (i % 64 + 1)) - 1 };
                    (rng.next_u64() & rng.next_u64()) ^ (rng.next_u64() & m)
                })
                .map(|w| w & if rows % 64 == 0 { !0 } else { (1u64 << (rows % 64)) - 1 })
                .collect();
            let scale = rng.normal(0.0, 1.0).abs() + 0.1;

            // sign_row == bias + scale·(2·masked_sum − total), per column
            let mut y = vec![0.0f32; cols];
            sign_row(&x, &blocks, &plane, wpc, scale, &bias, &mut y);
            let total = vecops::sum(&x);
            for j in 0..cols {
                let s = scalar::masked_sum_pc(&x, &plane[j * wpc..][..wpc], &blocks);
                let want = bias[j] + scale * (2.0 * s - total);
                assert_eq!(y[j].to_bits(), want.to_bits(), "sign col {j}");
            }

            // ternary_row: sign = plane ∩ fresh mask superset
            let mask: Vec<u64> = plane.iter().map(|&s| s | (rng.next_u64() & rng.next_u64())).collect();
            let mask: Vec<u64> = mask
                .iter()
                .map(|w| w & if rows % 64 == 0 { !0 } else { (1u64 << (rows % 64)) - 1 })
                .collect();
            let mut y = vec![0.0f32; cols];
            ternary_row(&x, &blocks, &plane, &mask, wpc, scale, &bias, &mut y);
            for j in 0..cols {
                let (p, n) =
                    scalar::ternary_sums(&x, &plane[j * wpc..][..wpc], &mask[j * wpc..][..wpc], &blocks);
                let want = bias[j] + scale * (p - n);
                assert_eq!(y[j].to_bits(), want.to_bits(), "ternary col {j}");
            }

            // coded_row / pow2_row vs scalar code_accumulate composition
            let bits = g.usize_in(1, 3);
            let k = 1usize << bits;
            let cwpc = (rows * bits).div_ceil(64);
            let codes: Vec<u64> = {
                let mut v = vec![0u64; cols * cwpc];
                for c in 0..cols {
                    for r in 0..rows {
                        let code = (rng.next_u64() as usize % k) as u64;
                        let bitpos = r * bits;
                        let (w, off) = (bitpos / 64, bitpos % 64);
                        v[c * cwpc + w] |= code << off;
                        if off + bits > 64 {
                            v[c * cwpc + w + 1] |= code >> (64 - off);
                        }
                    }
                }
                v
            };
            let codebook: Vec<f32> = (0..k).map(|_| rng.normal(0.0, 0.8)).collect();
            let mut y = vec![0.0f32; cols];
            coded_row(&x, &codes, cwpc, bits, &codebook, &bias, &mut y);
            for j in 0..cols {
                let mut acc = vec![0.0f32; k];
                scalar::code_accumulate(&x, &codes[j * cwpc..][..cwpc], bits as u32, &mut acc);
                let mut want = bias[j];
                for c in 0..k {
                    if codebook[c] != 0.0 {
                        want += codebook[c] * acc[c];
                    }
                }
                assert_eq!(y[j].to_bits(), want.to_bits(), "coded col {j}");
            }

            let pow2_cb: Vec<f32> = (0..k)
                .map(|c| {
                    let e = (c % 5) as i32 - 2;
                    let sg = if c % 3 == 0 { 0.0 } else if c % 3 == 1 { 1.0 } else { -1.0 };
                    sg * 2.0f32.powi(e)
                })
                .collect();
            let (exps, signs) = pow2_tables(&pow2_cb).unwrap();
            let mut y = vec![0.0f32; cols];
            pow2_row(&x, &codes, cwpc, bits, &exps, &signs, &bias, &mut y);
            for j in 0..cols {
                let mut acc = vec![0.0f32; k];
                scalar::code_accumulate(&x, &codes[j * cwpc..][..cwpc], bits as u32, &mut acc);
                let mut want = bias[j];
                for c in 0..k {
                    if signs[c] > 0.0 {
                        want += mul_pow2(acc[c], exps[c]);
                    } else if signs[c] < 0.0 {
                        want -= mul_pow2(acc[c], exps[c]);
                    }
                }
                assert_eq!(y[j].to_bits(), want.to_bits(), "pow2 col {j}");
            }
        });
    }

    #[test]
    fn coded_kernels_ignore_out_of_range_codes() {
        // lazy mmap planes may carry arbitrary (pre-verification) bits:
        // codes ≥ K must bin into unread accumulator slots, not crash or
        // perturb the combine. bits=2, K=3 → code 3 is hostile.
        let x = vec![1.0f32, 2.0, 4.0, 8.0];
        let bits = 2usize;
        // codes per row: [0, 3, 1, 3] — rows 1 and 3 are out of range
        let codes = vec![0b11_01_11_00u64];
        let codebook = vec![0.5f32, -1.0, 2.0]; // K=3
        let bias = vec![10.0f32];
        let mut y = vec![0.0f32; 1];
        coded_row(&x, &codes, 1, bits, &codebook, &bias, &mut y);
        // only rows 0 (code 0) and 2 (code 1) contribute
        assert_eq!(y[0], 10.0 + 0.5 * 1.0 + (-1.0) * 4.0);
    }
}
