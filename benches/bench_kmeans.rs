//! k-means benchmarks: the O(P log K) sorted-assignment step vs a naive
//! O(PK) scan (paper §4.1), cold k-means++ starts vs warm starts
//! (paper §3.3 / Fig. 10).

use lcquant::quant::kmeans::{kmeans_1d, kmeans_pp_init, midpoints, nearest_sorted, nearest_via_mids};
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

fn naive_assign(data: &[f32], centroids: &[f32]) -> Vec<u32> {
    data.iter()
        .map(|&x| {
            let mut best = 0u32;
            let mut bd = f32::INFINITY;
            for (i, &c) in centroids.iter().enumerate() {
                let d = (x - c).abs();
                if d < bd {
                    bd = d;
                    best = i as u32;
                }
            }
            best
        })
        .collect()
}

fn main() {
    println!("== bench_kmeans ==");
    let p = 266_200usize;
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..p).map(|_| rng.normal(0.0, 0.1)).collect();

    for &k in &[2usize, 16, 64, 256] {
        let centroids = kmeans_pp_init(&data, k, &mut rng);
        let s = bench(&format!("assign naive  O(PK)    K={k}"), 20, || {
            naive_assign(&data, &centroids)
        });
        println!("{}", s.report());
        let s = bench(&format!("assign bsearch O(PlogK) K={k}"), 20, || {
            data.iter()
                .map(|&x| nearest_sorted(&centroids, x) as u32)
                .collect::<Vec<u32>>()
        });
        println!("{}", s.report());
        let s = bench(&format!("assign midpoint scan    K={k}"), 20, || {
            let mids = midpoints(&centroids);
            data.iter()
                .map(|&x| nearest_via_mids(&mids, x) as u32)
                .collect::<Vec<u32>>()
        });
        println!("{}", s.report());
    }

    println!();
    for &k in &[4usize, 64] {
        let s = bench(&format!("kmeans cold (kmeans++ + Lloyd) K={k}"), 5, || {
            let mut rng = Rng::new(3);
            let mut c = kmeans_pp_init(&data, k, &mut rng);
            kmeans_1d(&data, &mut c, 200).iterations
        });
        println!("{}", s.report());
        // warm start: fully converged centroids (Lloyd can need hundreds of
        // iterations at K=64 on gaussian data; run to true convergence)
        let mut rng2 = Rng::new(3);
        let mut warm = kmeans_pp_init(&data, k, &mut rng2);
        kmeans_1d(&data, &mut warm, 20_000);
        let s = bench(&format!("kmeans warm (converged start)  K={k}"), 10, || {
            let mut c = warm.clone();
            kmeans_1d(&data, &mut c, 200).iterations
        });
        println!("{}", s.report());
    }

    // VGG scale: threaded Lloyd assignment (P >= 2M engages the pool)
    println!();
    let pv = 14_022_016usize;
    let mut rngv = Rng::new(9);
    let big: Vec<f32> = (0..pv).map(|_| rngv.normal(0.0, 0.1)).collect();
    for &k in &[2usize, 64] {
        let init = kmeans_pp_init(&big, k, &mut rngv);
        let s = bench(&format!("kmeans 10-iter P=14M (threaded) K={k}"), 3, || {
            let mut c = init.clone();
            kmeans_1d(&big, &mut c, 10).iterations
        });
        println!("{}", s.report());
    }
}
