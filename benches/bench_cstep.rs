//! C-step operator benchmarks (paper §4.2 runtime claims):
//! binarization O(P), binarization+scale O(P), ternarization+scale
//! O(P log P), powers-of-two O(1)/weight, fixed-codebook O(log K)/weight.
//! Sizes match the paper's nets: LeNet300 (266k), LeNet5 (430k), VGG (14M).

use lcquant::quant::{binary, fixed, pow2, ternary};
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

fn main() {
    println!("== bench_cstep: quantization operators ==");
    let sizes = [266_200usize, 430_500, 14_022_016];
    for &p in &sizes {
        let mut rng = Rng::new(42);
        let w: Vec<f32> = (0..p).map(|_| rng.normal(0.0, 0.1)).collect();
        let iters = if p > 1_000_000 { 10 } else { 40 };

        let s = bench(&format!("binarize            P={p}"), iters, || binary::binarize(&w));
        println!("{}  ({:.2} ns/weight)", s.report(), s.median_s * 1e9 / p as f64);

        let s = bench(&format!("binarize_with_scale P={p}"), iters, || {
            binary::binarize_with_scale(&w)
        });
        println!("{}  ({:.2} ns/weight)", s.report(), s.median_s * 1e9 / p as f64);

        let s = bench(&format!("ternarize_with_scale P={p}"), iters, || {
            ternary::ternarize_with_scale(&w)
        });
        println!("{}  ({:.2} ns/weight)", s.report(), s.median_s * 1e9 / p as f64);

        let s = bench(&format!("pow2 C=6            P={p}"), iters, || {
            pow2::quantize_pow2(&w, 6)
        });
        println!("{}  ({:.2} ns/weight)", s.report(), s.median_s * 1e9 / p as f64);

        let cb: Vec<f32> = (0..16).map(|i| -0.4 + i as f32 * 0.05).collect();
        let s = bench(&format!("fixed K=16          P={p}"), iters, || {
            fixed::quantize_fixed(&w, &cb)
        });
        println!("{}  ({:.2} ns/weight)", s.report(), s.median_s * 1e9 / p as f64);
        println!();
    }
}
