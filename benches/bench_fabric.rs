//! Fabric-router benchmarks (LeNet300 shapes, loopback TCP):
//!
//! * **router overhead**: the loadgen driving the same backend directly
//!   vs through a `RouterServer` — what the extra hop (decode, pick,
//!   re-frame, pooled backend connection) costs in req/s and tail
//!   latency;
//! * **failover blip**: the loadgen cluster scenario killing one of two
//!   replicas mid-run — every request must still be answered (failover)
//!   or shed typed, and the p99/max tail shows the cost of the blip;
//!
//! Results land in `BENCH_fabric.json` (`make bench-fabric`).

use lcquant::net::{
    loadgen, ClusterConfig, FabricConfig, LoadGenConfig, NetConfig, NetServer, RouterConfig,
    RouterServer, ShardConfig,
};
use lcquant::nn::MlpSpec;
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{PackedModel, Registry, ServerConfig};
use lcquant::util::backoff::BackoffCfg;
use lcquant::util::rng::Rng;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Quantize random LeNet300-shaped weights (no training: the bench cares
/// about wire + routing cost, not accuracy).
fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn server_cfg() -> ServerConfig {
    ServerConfig { max_batch: 64, max_wait: Duration::from_millis(2), pipeline_depth: 2 }
}

fn backend(reg: Arc<Registry>) -> NetServer {
    NetServer::start(
        reg,
        server_cfg(),
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 16,
            ..NetConfig::default()
        },
    )
    .expect("bind backend")
}

fn router(replicas: Vec<String>, probe_every: Duration) -> RouterServer {
    RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 16,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig { models: Vec::new(), replicas }],
            retry_budget: 4,
            deadline: Duration::from_secs(10),
            backoff: BackoffCfg { base: Duration::from_millis(1), cap: Duration::from_millis(10) },
            probe_every,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router")
}

fn main() {
    println!("== bench_fabric: router overhead + failover blip (LeNet300) ==");
    let model = packed_lenet300("binary", &Scheme::BinaryScale, 10);
    let mut registry = Registry::new();
    registry.insert(model).unwrap();
    let registry = Arc::new(registry);
    let per_conn = 128usize;

    // ---- router overhead: direct vs routed, 1/4/8 connections ----------
    let mut rows: Vec<(String, usize, f64, f32, f32, usize)> = Vec::new();
    for conns in [1usize, 4, 8] {
        // direct: loadgen straight at one backend
        let mut direct = backend(Arc::clone(&registry));
        let mut lg = LoadGenConfig::new(&direct.local_addr().to_string());
        lg.connections = conns;
        lg.requests_per_conn = per_conn;
        lg.seed = 7;
        let d = loadgen::run(&lg).expect("direct loadgen");
        println!(
            "direct  conns={conns}: {:>6.0} req/s  p50 {:.2}ms  p99 {:.2}ms  ({} ok, {} shed)",
            d.req_per_s(),
            d.p50_ms,
            d.p99_ms,
            d.ok,
            d.shed,
        );
        rows.push(("direct".into(), conns, d.req_per_s(), d.p50_ms, d.p99_ms, d.shed));
        direct.stop();

        // routed: the same load through a router over two replicas
        let b0 = backend(Arc::clone(&registry));
        let b1 = backend(Arc::clone(&registry));
        let mut rt =
            router(vec![b0.local_addr().to_string(), b1.local_addr().to_string()], Duration::ZERO);
        let mut lg = LoadGenConfig::new(&rt.local_addr().to_string());
        lg.connections = conns;
        lg.requests_per_conn = per_conn;
        lg.seed = 7;
        let r = loadgen::run(&lg).expect("routed loadgen");
        println!(
            "routed  conns={conns}: {:>6.0} req/s  p50 {:.2}ms  p99 {:.2}ms  \
             ({} ok, {} shed, {:.2}x direct p50)",
            r.req_per_s(),
            r.p50_ms,
            r.p99_ms,
            r.ok,
            r.shed,
            r.p50_ms / d.p50_ms.max(1e-6),
        );
        rows.push(("routed".into(), conns, r.req_per_s(), r.p50_ms, r.p99_ms, r.shed));
        rt.stop();
        let (mut b0, mut b1) = (b0, b1);
        b0.stop();
        b1.stop();
    }

    // ---- failover blip: kill one of two replicas mid-run ---------------
    println!("\n== failover blip: kill 1 of 2 replicas mid-run ==");
    let b0 = backend(Arc::clone(&registry));
    let b1 = backend(Arc::clone(&registry));
    let mut rt =
        router(vec![b0.local_addr().to_string(), b1.local_addr().to_string()], Duration::ZERO);
    let victim = Arc::new(Mutex::new(Some(b0)));
    let kill_slot = Arc::clone(&victim);
    let total = 8 * per_conn as u64;
    let mut lg = LoadGenConfig::new(&rt.local_addr().to_string());
    lg.connections = 8;
    lg.requests_per_conn = per_conn;
    lg.seed = 7;
    let report = loadgen::run_cluster(
        &ClusterConfig { load: lg, kill_at: Some(total / 4), restart_at: None },
        move || {
            if let Some(mut s) = kill_slot.lock().unwrap().take() {
                s.stop();
            }
        },
        || {},
    )
    .expect("cluster loadgen");
    println!("{}", report.summary());
    let snap = rt.stats();
    assert_eq!(report.load.failed, 0, "failover must leave no un-typed failures");
    rt.stop();
    let mut b1 = b1;
    b1.stop();
    if let Some(mut s) = victim.lock().unwrap().take() {
        s.stop();
    }

    // ---- BENCH_fabric.json ---------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"fabric\",\n");
    json.push_str(&format!(
        "  \"threads\": {},\n  \"requests_per_conn\": {per_conn},\n  \"overhead_sweep\": [\n",
        lcquant::linalg::num_threads(),
    ));
    for (i, (path, conns, req_s, p50, p99, shed)) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    {{\"path\": \"{path}\", \"connections\": {conns}, \"req_per_s\": {req_s:.0}, \
             \"p50_ms\": {p50:.3}, \"p99_ms\": {p99:.3}, \"shed\": {shed}}}{comma}\n"
        ));
    }
    json.push_str("  ],\n  \"failover_blip\": {\n");
    json.push_str(&format!(
        "    \"requests\": {total}, \"kill_at\": {}, \"ok\": {}, \"shed\": {}, \
         \"failed\": {}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"max_ms\": {:.3},\n",
        total / 4,
        report.load.ok,
        report.load.shed,
        report.load.failed,
        report.load.p50_ms,
        report.load.p99_ms,
        report.load.max_ms,
    ));
    json.push_str(&format!(
        "    \"router_retries\": {}, \"router_failovers\": {}, \
         \"router_health_transitions\": {}\n  }}\n}}\n",
        snap.retries, snap.failovers, snap.health_transitions,
    ));
    match std::fs::write("BENCH_fabric.json", &json) {
        Ok(()) => println!("wrote BENCH_fabric.json"),
        Err(e) => eprintln!("could not write BENCH_fabric.json: {e}"),
    }
}
