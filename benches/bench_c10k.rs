//! C10K connection-plane benchmark (LeNet300 shapes, loopback TCP):
//! the connection-count scaling curve of the event-driven plane.
//!
//! For each point, a herd of raw idle connections camps on the server
//! (handshaken, then silent — they cost the plane a slab slot and a
//! `FrameReader`, not a thread), while 8 active connections drive
//! pipelined traffic through `loadgen::run`. The sweep crosses total
//! connection count (64 / 512 / 2048) with pipeline window (1 / 8):
//! a flat req/s and p99 across the herd axis is the epoll plane doing
//! its job; the pipeline axis shows what in-flight ids buy on loopback
//! RTTs.
//!
//! Points whose file-descriptor bill exceeds the process's
//! `RLIMIT_NOFILE` soft limit are skipped with a note (both socket ends
//! live in this process, so a point costs ~2x its connection count).
//!
//! Results land in `BENCH_net.json` (`make bench-c10k`).

use lcquant::net::proto::{self, Frame, FrameReader};
use lcquant::net::{loadgen, LoadGenConfig, NetConfig, NetServer};
use lcquant::nn::MlpSpec;
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{PackedModel, Registry, ServerConfig};
use lcquant::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// Quantize random LeNet300-shaped weights (no training: the bench cares
/// about connection-plane cost, not accuracy).
fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

/// Handshake one raw connection (client preamble out, server preamble +
/// hello in) and return it to be camped.
fn camp_one(addr: &str) -> std::io::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(&proto::encode_preamble())?;
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre)?;
    proto::decode_preamble(&pre)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return Ok(stream),
            Ok(Some(f)) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("expected hello, got {f:?}"),
                ))
            }
            Ok(None) => continue,
            Err(e) => {
                return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
            }
        }
    }
}

/// Soft `RLIMIT_NOFILE` from `/proc/self/limits` (`None` off-Linux).
fn nofile_soft_limit() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/limits").ok()?;
    for line in text.lines() {
        if line.starts_with("Max open files") {
            let soft = line.split_whitespace().nth(3)?;
            if soft == "unlimited" {
                return Some(u64::MAX);
            }
            return soft.parse().ok();
        }
    }
    None
}

fn main() {
    println!("== bench_c10k: connection-count scaling of the epoll plane (LeNet300) ==");
    let mut registry = Registry::new();
    registry.insert(packed_lenet300("binary", &Scheme::BinaryScale, 10)).unwrap();
    let registry = Arc::new(registry);
    let active = 8usize;
    let per_conn = 128usize;
    let limit = nofile_soft_limit();

    let mut rows: Vec<(usize, usize, f64, f32, f32, usize)> = Vec::new();
    for conns in [64usize, 512, 2048] {
        let need = (2 * conns + 256) as u64;
        if let Some(l) = limit {
            if l < need {
                println!("conns={conns}: skipped (RLIMIT_NOFILE soft limit {l} < {need} needed)");
                continue;
            }
        }
        let mut server = NetServer::start(
            Arc::clone(&registry),
            ServerConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                pipeline_depth: 2,
            },
            NetConfig {
                bind_addr: "127.0.0.1:0".to_string(),
                max_connections: conns + 64,
                net_threads: 2,
                max_inflight: 32,
                ..NetConfig::default()
            },
        )
        .expect("bind server");
        let addr = server.local_addr().to_string();

        // camp the herd: total connection count = herd + active drivers
        let herd_n = conns.saturating_sub(active);
        let mut herd = Vec::with_capacity(herd_n);
        for _ in 0..herd_n {
            match camp_one(&addr) {
                Ok(s) => herd.push(s),
                Err(e) => {
                    eprintln!("conns={conns}: herd handshake failed: {e}");
                    break;
                }
            }
        }

        for window in [1usize, 8] {
            let mut lg = LoadGenConfig::new(&addr);
            lg.connections = active;
            lg.requests_per_conn = per_conn;
            lg.pipeline = window;
            lg.seed = 7;
            let r = loadgen::run(&lg).expect("loadgen");
            println!(
                "conns={conns:>4} (herd {:>4}) pipeline={window}: {:>7.0} req/s  \
                 p50 {:.2}ms  p99 {:.2}ms  ({} ok, {} shed, {} failed)",
                herd.len(),
                r.req_per_s(),
                r.p50_ms,
                r.p99_ms,
                r.ok,
                r.shed,
                r.failed,
            );
            rows.push((conns, window, r.req_per_s(), r.p50_ms, r.p99_ms, r.shed));
        }
        drop(herd);
        server.stop();
    }

    // ---- BENCH_net.json -------------------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"net\",\n");
    json.push_str(&format!(
        "  \"threads\": {},\n  \"active_connections\": {active},\n  \
         \"requests_per_conn\": {per_conn},\n  \"c10k_sweep\": [\n",
        lcquant::linalg::num_threads(),
    ));
    for (i, (conns, window, req_s, p50, p99, shed)) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    {{\"connections\": {conns}, \"pipeline\": {window}, \
             \"req_per_s\": {req_s:.0}, \"p50_ms\": {p50:.3}, \"p99_ms\": {p99:.3}, \
             \"shed\": {shed}}}{comma}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("wrote BENCH_net.json"),
        Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
    }
}
