//! End-to-end LC iteration cost, LeNet300 K∈{2,64}: wall-clock of one
//! (L step + C step + multiplier update) cycle, and the L/C split — the
//! paper's §3.3 claim is that C-step time is negligible.
//!
//! Runs on the flat parameter plane: w_C and λ are weight-arena-length
//! buffers, the C step quantizes per-layer arena views through reusable
//! `QuantOut`s, and the multiplier update is fused with the feasibility
//! norm.

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov, PenaltyState};
use lcquant::coordinator::{Backend, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::linalg::vecops;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::{LayerQuantizer, QuantOut, Scheme};
use lcquant::util::timer::{bench, Timer};

fn main() {
    println!("== bench_e2e: one LC iteration (LeNet300, 20 SGD steps/L-step) ==");
    let mut data = SynthMnist::generate(1_024, 1);
    data.subtract_mean(None);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, data, None, 128, 1);
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, 0.95);
    let l_steps = 20;

    for &k in &[2usize, 64] {
        let n_layers = layout.n_layers();
        let mut quantizers: Vec<LayerQuantizer> = (0..n_layers)
            .map(|l| LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, l as u64))
            .collect();
        let mut outs: Vec<QuantOut> = (0..n_layers).map(|_| QuantOut::default()).collect();
        // initialize wc/lambda (flat, allocated once per K)
        let mut wc = vec![0.0f32; layout.w_len()];
        let mut lambda = vec![0.0f32; layout.w_len()];
        for l in 0..n_layers {
            quantizers[l].compress_into(backend.params().w_layer(l), &mut outs[l]);
            wc[layout.w_range(l)].copy_from_slice(&outs[l].wc);
        }
        let mu = 0.01f32;

        let mut l_time = 0.0f64;
        let mut c_time = 0.0f64;
        let s = bench(&format!("LC iteration K={k}"), 10, || {
            // L step
            let t = Timer::start();
            {
                let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu };
                run_sgd(&mut backend, &mut opt, l_steps, 0.02, Some(&penalty));
            }
            l_time += t.elapsed_s();
            // C step + fused multiplier/feasibility update
            let t = Timer::start();
            for l in 0..n_layers {
                quantizers[l].compress_into(backend.params().w_layer(l), &mut outs[l]);
                wc[layout.w_range(l)].copy_from_slice(&outs[l].wc);
            }
            let _ = vecops::update_multipliers_fused(
                &mut lambda,
                backend.params().w_flat(),
                &wc,
                mu,
            );
            c_time += t.elapsed_s();
        });
        println!("{}", s.report());
        // l_time/c_time include warmup runs; the *ratio* is what matters.
        let frac = c_time / (l_time + c_time);
        println!(
            "  split: C step is {:.2}% of the LC cycle (paper: negligible)",
            100.0 * frac
        );
    }
}
