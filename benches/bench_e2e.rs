//! End-to-end LC iteration cost, LeNet300 K∈{2,64}: wall-clock of one
//! (L step + C step + multiplier update) cycle, and the L/C split — the
//! paper's §3.3 claim is that C-step time is negligible.

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov, PenaltyState};
use lcquant::coordinator::{Backend, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::util::timer::{bench, Timer};

fn main() {
    println!("== bench_e2e: one LC iteration (LeNet300, 20 SGD steps/L-step) ==");
    let mut data = SynthMnist::generate(1_024, 1);
    data.subtract_mean(None);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, data, None, 128, 1);
    let mut opt = FlatNesterov::new(&backend.weights(), &backend.biases(), 0.95);
    let l_steps = 20;

    for &k in &[2usize, 64] {
        let mut quantizers: Vec<LayerQuantizer> = (0..backend.n_layers())
            .map(|l| LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, l as u64))
            .collect();
        // initialize wc/lambda
        let w0 = backend.weights();
        let mut wc: Vec<Vec<f32>> = w0
            .iter()
            .zip(quantizers.iter_mut())
            .map(|(wl, q)| q.compress(wl).wc)
            .collect();
        let mut lambda: Vec<Vec<f32>> = w0.iter().map(|l| vec![0.0; l.len()]).collect();
        let mu = 0.01f32;

        let mut l_time = 0.0f64;
        let mut c_time = 0.0f64;
        let s = bench(&format!("LC iteration K={k}"), 10, || {
            // L step
            let t = Timer::start();
            let penalty = PenaltyState { wc: wc.clone(), lambda: lambda.clone(), mu };
            run_sgd(&mut backend, &mut opt, l_steps, 0.02, Some(&penalty));
            l_time += t.elapsed_s();
            // C step
            let t = Timer::start();
            let w = backend.weights();
            for (l, q) in quantizers.iter_mut().enumerate() {
                let out = q.compress(&w[l]);
                wc[l] = out.wc;
            }
            for l in 0..w.len() {
                lcquant::linalg::vecops::update_multipliers(&mut lambda[l], &w[l], &wc[l], mu);
            }
            c_time += t.elapsed_s();
        });
        println!("{}", s.report());
        // l_time/c_time include warmup runs; the *ratio* is what matters.
        let frac = c_time / (l_time + c_time);
        println!(
            "  split: C step is {:.2}% of the LC cycle (paper: negligible)",
            100.0 * frac
        );
    }
}
