//! One-command regeneration of every paper table/figure at quick scale —
//! `cargo bench` therefore reproduces the paper's evaluation section
//! end-to-end (rows land in results-bench/, shapes discussed in
//! EXPERIMENTS.md).

use lcquant::experiments::{self, Scale};
use lcquant::util::timer::Timer;

fn main() {
    lcquant::util::log::set_level(lcquant::util::log::Level::Warn);
    let out = "results-bench";
    std::fs::create_dir_all(out).expect("mkdir");
    println!("== bench_experiments: regenerating all paper tables/figures (quick scale) ==");
    for id in experiments::ALL {
        let t = Timer::start();
        match experiments::run(id, out, Scale::Quick, 42) {
            Ok(()) => println!("[{id}] done in {:.1}s", t.elapsed_s()),
            Err(e) => println!("[{id}] FAILED: {e:#}"),
        }
    }
}
