//! L-step throughput: minibatch loss+grad+update steps per second on the
//! native backend (and the PJRT backend when artifacts are present),
//! LeNet300 shapes, batch 128. The C step is benchmarked separately
//! (bench_cstep) — the paper's claim "C-step runtime is negligible vs the
//! L step" is checked in bench_e2e.
//!
//! Two parameter-plane strategies are measured head-to-head and written to
//! `BENCH_lstep.json`:
//!
//! * **legacy** — the pre-refactor per-layer plane: clone the parameters
//!   into `Vec<Vec<f32>>`, allocate gradients per step, run a per-layer
//!   Nesterov loop, then copy everything back with `set_weights`/
//!   `set_biases` (two full-parameter copies per minibatch step);
//! * **flat** — the arena plane: gradients stream into one reusable
//!   `GradBuffer` and the fused `FlatNesterov::step` updates the backend's
//!   `ParamSet` in place (zero copies, zero steady-state allocation).
//!
//! A counting global allocator reports allocations per step for both (the
//! strict zero-allocation assertions — single-threaded *and* pooled —
//! live in `rust/tests/flat_params.rs`).
//!
//! Two further head-to-head measurements are written to `BENCH_pool.json`:
//!
//! * **dispatch substrate** — the legacy per-call `thread::scope` band
//!   fan-out (reconstructed here verbatim) vs the persistent multi-task
//!   `linalg::pool` the kernels now dispatch through (publish into a task
//!   slot + lock-free generation-tagged part claims), on a gemm-shaped
//!   band task;
//! * **vecops substrate** — the 8-lane SIMD-explicit kernels vs their
//!   `vecops::scalar` references on LeNet300-arena-sized buffers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lcquant::coordinator::sgd_driver::{FlatNesterov, PenaltyState};
#[cfg(feature = "pjrt")]
use lcquant::coordinator::sgd_driver::run_sgd;
use lcquant::coordinator::{Backend, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::linalg::{pool, vecops};
use lcquant::nn::{GradBuffer, Mlp, MlpSpec};
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// One pre-refactor-style step: allocate gradients, update cloned
/// per-layer parameter vectors, copy the full parameter set back into the
/// backend — the exact traffic pattern `run_sgd` used before the flat
/// parameter plane. Keep in lockstep with `legacy_run_sgd` in
/// `rust/tests/flat_params.rs`, the golden-parity reference for the same
/// algorithm (bench targets can't share test code without a lib export).
#[allow(clippy::too_many_arguments)]
fn legacy_step(
    backend: &mut NativeBackend,
    w: &mut [Vec<f32>],
    b: &mut [Vec<f32>],
    vw: &mut [Vec<f32>],
    vb: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    penalty: Option<(&[Vec<f32>], &[Vec<f32>], f32)>,
) -> f32 {
    let (loss, grads) = backend.next_loss_grads();
    let m = momentum;
    for l in 0..w.len() {
        let (wl, vl) = (&mut w[l], &mut vw[l]);
        let gl = grads.w_layer(l);
        match penalty {
            Some((wc, lam, mu)) if mu > 0.0 => {
                for i in 0..wl.len() {
                    let g = gl[i] + mu * (wl[i] - wc[l][i]) - lam[l][i];
                    vl[i] = m * vl[i] - lr * g;
                    wl[i] += m * vl[i] - lr * g;
                }
            }
            _ => {
                for i in 0..wl.len() {
                    vl[i] = m * vl[i] - lr * gl[i];
                    wl[i] += m * vl[i] - lr * gl[i];
                }
            }
        }
        let (bl, vbl) = (&mut b[l], &mut vb[l]);
        let gbl = grads.b_layer(l);
        for i in 0..bl.len() {
            vbl[i] = m * vbl[i] - lr * gbl[i];
            bl[i] += m * vbl[i] - lr * gbl[i];
        }
    }
    backend.set_weights(w);
    backend.set_biases(b);
    loss
}

/// (median steps/s, allocations per step) for a closure running one step.
fn measure<F: FnMut()>(name: &str, iters: usize, mut step: F) -> (f64, f64) {
    let s = bench(name, iters, &mut step);
    println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);
    let probe = 50u64;
    let before = alloc_count();
    for _ in 0..probe {
        step();
    }
    let per_step = (alloc_count() - before) as f64 / probe as f64;
    println!("    allocations/step: {per_step:.1}");
    (1.0 / s.median_s, per_step)
}

/// The pre-pool dispatch, reconstructed verbatim: split the output into
/// per-thread row bands (allocating the band table) and fan out with a
/// fresh `thread::scope` — what every threaded kernel paid per call before
/// the persistent pool.
fn scoped_run_bands<F>(m: usize, n: usize, out: &mut [f32], f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let nt = lcquant::linalg::num_threads();
    let per = m.div_ceil(nt);
    let mut bands = Vec::new();
    let mut rest = out;
    let mut start = 0;
    while start < m {
        let end = (start + per).min(m);
        let (head, tail) = rest.split_at_mut((end - start) * n);
        bands.push((start..end, head));
        rest = tail;
        start = end;
    }
    std::thread::scope(|s| {
        for (range, chunk) in bands {
            let fref = &f;
            s.spawn(move || fref(range, chunk));
        }
    });
}

/// Dispatch-substrate and vecops-substrate head-to-heads → BENCH_pool.json.
fn bench_pool_and_simd() {
    let nt = lcquant::linalg::num_threads();
    println!("\n== dispatch substrate ({nt} threads) ==");
    // A gemm-band-shaped task: touch every output row once. Small enough
    // that dispatch overhead dominates — exactly the regime of the
    // per-minibatch L-step kernels.
    let (m, n) = (256usize, 300usize);
    let mut out = vec![0.0f32; m * n];
    let touch = |rows: std::ops::Range<usize>, band: &mut [f32]| {
        for (local, r) in rows.enumerate() {
            let row = &mut band[local * n..(local + 1) * n];
            for v in row.iter_mut() {
                *v += r as f32;
            }
        }
    };
    let s_scoped = bench("band dispatch via thread::scope", 200, || {
        scoped_run_bands(m, n, &mut out, touch);
    });
    println!("{}  ({:.0} dispatches/s)", s_scoped.report(), 1.0 / s_scoped.median_s);
    let s_pool = bench("band dispatch via persistent pool", 200, || {
        pool::run_bands(m, n, &mut out, touch);
    });
    println!("{}  ({:.0} dispatches/s)", s_pool.report(), 1.0 / s_pool.median_s);
    let dispatch_speedup = s_scoped.median_s / s_pool.median_s;
    println!("pool dispatch speedup: {dispatch_speedup:.2}x");

    println!("\n== vecops substrate (LeNet300 weight arena, 266,200 f32) ==");
    let p1 = 266_200usize;
    let mut rng = Rng::new(7);
    let mut w = vec![0.0f32; p1];
    let mut v = vec![0.0f32; p1];
    let mut g = vec![0.0f32; p1];
    let mut wc = vec![0.0f32; p1];
    let mut lam = vec![0.0f32; p1];
    rng.fill_normal(&mut w, 0.0, 0.5);
    rng.fill_normal(&mut g, 0.0, 0.1);
    rng.fill_normal(&mut wc, 0.0, 0.5);
    rng.fill_normal(&mut lam, 0.0, 0.05);
    let s_scal = bench("nesterov_step_penalized (scalar ref)", 100, || {
        vecops::scalar::nesterov_step_penalized(
            &mut w, &g, &mut v, &wc, &lam, 0.01, 0.05, 0.9,
        );
    });
    println!("{}  ({:.0}M elem/s)", s_scal.report(), p1 as f64 / s_scal.median_s / 1e6);
    let s_simd = bench("nesterov_step_penalized (8-lane SIMD)", 100, || {
        vecops::nesterov_step_penalized(&mut w, &g, &mut v, &wc, &lam, 0.01, 0.05, 0.9);
    });
    println!("{}  ({:.0}M elem/s)", s_simd.report(), p1 as f64 / s_simd.median_s / 1e6);
    let step_speedup = s_scal.median_s / s_simd.median_s;

    let idx: Vec<u32> = (0..p1).map(|_| rng.below(p1) as u32).collect();
    let g_scal = bench("gather_sum (scalar ref)", 100, || {
        vecops::scalar::gather_sum(&w, &idx)
    });
    println!("{}  ({:.0}M gathers/s)", g_scal.report(), p1 as f64 / g_scal.median_s / 1e6);
    let g_simd = bench("gather_sum (8-accumulator)", 100, || {
        vecops::gather_sum(&w, &idx)
    });
    println!("{}  ({:.0}M gathers/s)", g_simd.report(), p1 as f64 / g_simd.median_s / 1e6);
    let gather_speedup = g_scal.median_s / g_simd.median_s;
    println!(
        "SIMD speedup: penalized step {step_speedup:.2}x, gather {gather_speedup:.2}x"
    );

    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"pool\",\n");
    json.push_str(&format!("  \"threads\": {nt},\n"));
    json.push_str("  \"dispatch\": {\n");
    json.push_str(&format!(
        "    \"task\": \"touch {m}x{n} row bands\",\n    \"scoped_spawn_us\": {:.2},\n",
        s_scoped.median_s * 1e6
    ));
    json.push_str(&format!("    \"pool_us\": {:.2},\n", s_pool.median_s * 1e6));
    json.push_str(&format!("    \"speedup\": {dispatch_speedup:.3}\n  }},\n"));
    json.push_str("  \"vecops\": {\n");
    json.push_str(&format!(
        "    \"arena\": {p1},\n    \"penalized_step_scalar_melems_s\": {:.1},\n",
        p1 as f64 / s_scal.median_s / 1e6
    ));
    json.push_str(&format!(
        "    \"penalized_step_simd_melems_s\": {:.1},\n",
        p1 as f64 / s_simd.median_s / 1e6
    ));
    json.push_str(&format!("    \"penalized_step_speedup\": {step_speedup:.3},\n"));
    json.push_str(&format!(
        "    \"gather_scalar_melems_s\": {:.1},\n    \"gather_simd_melems_s\": {:.1},\n",
        p1 as f64 / g_scal.median_s / 1e6,
        p1 as f64 / g_simd.median_s / 1e6
    ));
    json.push_str(&format!("    \"gather_speedup\": {gather_speedup:.3}\n  }}\n}}\n"));
    match std::fs::write("BENCH_pool.json", &json) {
        Ok(()) => println!("wrote BENCH_pool.json"),
        Err(e) => eprintln!("could not write BENCH_pool.json: {e}"),
    }
}

fn main() {
    println!("== bench_lstep ==");
    let mut data = SynthMnist::generate(1_024, 1);
    data.subtract_mean(None);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, data.clone(), None, 128, 1);
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, 0.95);

    // ---- legacy parameter plane (per-layer copies + set_weights) --------
    let mut w = backend.weights();
    let mut b = backend.biases();
    let mut vw: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let mut vb: Vec<Vec<f32>> = b.iter().map(|l| vec![0.0; l.len()]).collect();
    let (legacy_sps, legacy_allocs) =
        measure("legacy L-step (batch=128, no penalty)", 30, || {
            legacy_step(&mut backend, &mut w, &mut b, &mut vw, &mut vb, 0.05, 0.95, None);
        });

    let wc_l: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let lam_l: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let (legacy_pen_sps, _) = measure("legacy L-step (batch=128, with penalty)", 30, || {
        legacy_step(
            &mut backend,
            &mut w,
            &mut b,
            &mut vw,
            &mut vb,
            0.05,
            0.95,
            Some((&wc_l, &lam_l, 0.01)),
        );
    });

    // ---- flat parameter plane (in-place fused step; this is exactly the
    //      inner loop of `run_sgd`, with the per-L-step GradBuffer held
    //      across iterations as the LC loop does) -------------------------
    let mut grads = GradBuffer::zeros(layout.clone());
    let (flat_sps, flat_allocs) = measure("flat L-step (batch=128, no penalty)", 30, || {
        backend.next_loss_grads_into(&mut grads);
        opt.step(backend.params_mut(), &grads, 0.05, None);
    });

    let wc = vec![0.0f32; layout.w_len()];
    let lambda = vec![0.0f32; layout.w_len()];
    let (flat_pen_sps, flat_pen_allocs) =
        measure("flat L-step (batch=128, with penalty)", 30, || {
            backend.next_loss_grads_into(&mut grads);
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.01 };
            opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
        });

    println!(
        "speedup (no penalty): {:.2}x   (with penalty): {:.2}x",
        flat_sps / legacy_sps,
        flat_pen_sps / legacy_pen_sps
    );

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"lstep\",\n  \"net\": \"lenet300\",\n  \"batch\": 128,\n");
    json.push_str("  \"before\": {\n");
    json.push_str("    \"plane\": \"per-layer copies (pre-refactor)\",\n");
    json.push_str(&format!("    \"steps_per_s\": {legacy_sps:.2},\n"));
    json.push_str(&format!("    \"steps_per_s_penalty\": {legacy_pen_sps:.2},\n"));
    json.push_str(&format!("    \"allocs_per_step\": {legacy_allocs:.1}\n"));
    json.push_str("  },\n  \"after\": {\n");
    json.push_str("    \"plane\": \"flat ParamSet arena\",\n");
    json.push_str(&format!("    \"steps_per_s\": {flat_sps:.2},\n"));
    json.push_str(&format!("    \"steps_per_s_penalty\": {flat_pen_sps:.2},\n"));
    json.push_str(&format!("    \"allocs_per_step\": {flat_allocs:.1},\n"));
    json.push_str(&format!("    \"allocs_per_step_penalty\": {flat_pen_allocs:.1}\n"));
    json.push_str("  },\n");
    json.push_str(&format!("  \"speedup\": {:.3},\n", flat_sps / legacy_sps));
    json.push_str(&format!(
        "  \"speedup_penalty\": {:.3}\n}}\n",
        flat_pen_sps / legacy_pen_sps
    ));
    match std::fs::write("BENCH_lstep.json", &json) {
        Ok(()) => println!("wrote BENCH_lstep.json"),
        Err(e) => eprintln!("could not write BENCH_lstep.json: {e}"),
    }

    bench_pool_and_simd();

    // PJRT backend, if compiled in and artifacts were built
    #[cfg(feature = "pjrt")]
    {
        let dir = lcquant::runtime::Engine::default_dir();
        if lcquant::runtime::Engine::available(&dir) {
            let engine = lcquant::runtime::Engine::open(&dir).expect("engine");
            let mut rng = Rng::new(2);
            let (train, _) = data.split(0.1, &mut rng);
            let mut pjrt = lcquant::runtime::PjrtBackend::new(engine, "lenet300", train, None, 3)
                .expect("pjrt backend");
            // warm the executable cache
            let _ = pjrt.next_loss_grads();
            let mut popt = FlatNesterov::new(pjrt.layout(), 0.95);
            let s = bench("pjrt L-step (batch from artifact)", 30, || {
                run_sgd(&mut pjrt, &mut popt, 1, 0.05, None)
            });
            println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);
        } else {
            println!("(artifacts not built; skipping PJRT L-step — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the 'pjrt' feature; skipping PJRT L-step)");
}
