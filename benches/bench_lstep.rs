//! L-step throughput: minibatch loss+grad+update steps per second on the
//! native backend (and the PJRT backend when artifacts are present),
//! LeNet300 shapes, batch 128. The C step is benchmarked separately
//! (bench_cstep) — the paper's claim "C-step runtime is negligible vs the
//! L step" is checked in bench_e2e.
//!
//! Two parameter-plane strategies are measured head-to-head and written to
//! `BENCH_lstep.json`:
//!
//! * **legacy** — the pre-refactor per-layer plane: clone the parameters
//!   into `Vec<Vec<f32>>`, allocate gradients per step, run a per-layer
//!   Nesterov loop, then copy everything back with `set_weights`/
//!   `set_biases` (two full-parameter copies per minibatch step);
//! * **flat** — the arena plane: gradients stream into one reusable
//!   `GradBuffer` and the fused `FlatNesterov::step` updates the backend's
//!   `ParamSet` in place (zero copies, zero steady-state allocation).
//!
//! A counting global allocator reports allocations per step for both
//! (thread-spawns inside the threaded gemm also allocate, so the flat
//! number is small rather than zero here; the strict zero-allocation
//! assertion lives in `rust/tests/flat_params.rs` on sub-threshold shapes).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lcquant::coordinator::sgd_driver::{FlatNesterov, PenaltyState};
#[cfg(feature = "pjrt")]
use lcquant::coordinator::sgd_driver::run_sgd;
use lcquant::coordinator::{Backend, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::{GradBuffer, Mlp, MlpSpec};
#[cfg(feature = "pjrt")]
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// One pre-refactor-style step: allocate gradients, update cloned
/// per-layer parameter vectors, copy the full parameter set back into the
/// backend — the exact traffic pattern `run_sgd` used before the flat
/// parameter plane. Keep in lockstep with `legacy_run_sgd` in
/// `rust/tests/flat_params.rs`, the golden-parity reference for the same
/// algorithm (bench targets can't share test code without a lib export).
#[allow(clippy::too_many_arguments)]
fn legacy_step(
    backend: &mut NativeBackend,
    w: &mut [Vec<f32>],
    b: &mut [Vec<f32>],
    vw: &mut [Vec<f32>],
    vb: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    penalty: Option<(&[Vec<f32>], &[Vec<f32>], f32)>,
) -> f32 {
    let (loss, grads) = backend.next_loss_grads();
    let m = momentum;
    for l in 0..w.len() {
        let (wl, vl) = (&mut w[l], &mut vw[l]);
        let gl = grads.w_layer(l);
        match penalty {
            Some((wc, lam, mu)) if mu > 0.0 => {
                for i in 0..wl.len() {
                    let g = gl[i] + mu * (wl[i] - wc[l][i]) - lam[l][i];
                    vl[i] = m * vl[i] - lr * g;
                    wl[i] += m * vl[i] - lr * g;
                }
            }
            _ => {
                for i in 0..wl.len() {
                    vl[i] = m * vl[i] - lr * gl[i];
                    wl[i] += m * vl[i] - lr * gl[i];
                }
            }
        }
        let (bl, vbl) = (&mut b[l], &mut vb[l]);
        let gbl = grads.b_layer(l);
        for i in 0..bl.len() {
            vbl[i] = m * vbl[i] - lr * gbl[i];
            bl[i] += m * vbl[i] - lr * gbl[i];
        }
    }
    backend.set_weights(w);
    backend.set_biases(b);
    loss
}

/// (median steps/s, allocations per step) for a closure running one step.
fn measure<F: FnMut()>(name: &str, iters: usize, mut step: F) -> (f64, f64) {
    let s = bench(name, iters, &mut step);
    println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);
    let probe = 50u64;
    let before = alloc_count();
    for _ in 0..probe {
        step();
    }
    let per_step = (alloc_count() - before) as f64 / probe as f64;
    println!("    allocations/step: {per_step:.1}");
    (1.0 / s.median_s, per_step)
}

fn main() {
    println!("== bench_lstep ==");
    let mut data = SynthMnist::generate(1_024, 1);
    data.subtract_mean(None);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, data.clone(), None, 128, 1);
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, 0.95);

    // ---- legacy parameter plane (per-layer copies + set_weights) --------
    let mut w = backend.weights();
    let mut b = backend.biases();
    let mut vw: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let mut vb: Vec<Vec<f32>> = b.iter().map(|l| vec![0.0; l.len()]).collect();
    let (legacy_sps, legacy_allocs) =
        measure("legacy L-step (batch=128, no penalty)", 30, || {
            legacy_step(&mut backend, &mut w, &mut b, &mut vw, &mut vb, 0.05, 0.95, None);
        });

    let wc_l: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let lam_l: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let (legacy_pen_sps, _) = measure("legacy L-step (batch=128, with penalty)", 30, || {
        legacy_step(
            &mut backend,
            &mut w,
            &mut b,
            &mut vw,
            &mut vb,
            0.05,
            0.95,
            Some((&wc_l, &lam_l, 0.01)),
        );
    });

    // ---- flat parameter plane (in-place fused step; this is exactly the
    //      inner loop of `run_sgd`, with the per-L-step GradBuffer held
    //      across iterations as the LC loop does) -------------------------
    let mut grads = GradBuffer::zeros(layout.clone());
    let (flat_sps, flat_allocs) = measure("flat L-step (batch=128, no penalty)", 30, || {
        backend.next_loss_grads_into(&mut grads);
        opt.step(backend.params_mut(), &grads, 0.05, None);
    });

    let wc = vec![0.0f32; layout.w_len()];
    let lambda = vec![0.0f32; layout.w_len()];
    let (flat_pen_sps, flat_pen_allocs) =
        measure("flat L-step (batch=128, with penalty)", 30, || {
            backend.next_loss_grads_into(&mut grads);
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.01 };
            opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
        });

    println!(
        "speedup (no penalty): {:.2}x   (with penalty): {:.2}x",
        flat_sps / legacy_sps,
        flat_pen_sps / legacy_pen_sps
    );

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"lstep\",\n  \"net\": \"lenet300\",\n  \"batch\": 128,\n");
    json.push_str("  \"before\": {\n");
    json.push_str("    \"plane\": \"per-layer copies (pre-refactor)\",\n");
    json.push_str(&format!("    \"steps_per_s\": {legacy_sps:.2},\n"));
    json.push_str(&format!("    \"steps_per_s_penalty\": {legacy_pen_sps:.2},\n"));
    json.push_str(&format!("    \"allocs_per_step\": {legacy_allocs:.1}\n"));
    json.push_str("  },\n  \"after\": {\n");
    json.push_str("    \"plane\": \"flat ParamSet arena\",\n");
    json.push_str(&format!("    \"steps_per_s\": {flat_sps:.2},\n"));
    json.push_str(&format!("    \"steps_per_s_penalty\": {flat_pen_sps:.2},\n"));
    json.push_str(&format!("    \"allocs_per_step\": {flat_allocs:.1},\n"));
    json.push_str(&format!("    \"allocs_per_step_penalty\": {flat_pen_allocs:.1}\n"));
    json.push_str("  },\n");
    json.push_str(&format!("  \"speedup\": {:.3},\n", flat_sps / legacy_sps));
    json.push_str(&format!(
        "  \"speedup_penalty\": {:.3}\n}}\n",
        flat_pen_sps / legacy_pen_sps
    ));
    match std::fs::write("BENCH_lstep.json", &json) {
        Ok(()) => println!("wrote BENCH_lstep.json"),
        Err(e) => eprintln!("could not write BENCH_lstep.json: {e}"),
    }

    // PJRT backend, if compiled in and artifacts were built
    #[cfg(feature = "pjrt")]
    {
        let dir = lcquant::runtime::Engine::default_dir();
        if lcquant::runtime::Engine::available(&dir) {
            let engine = lcquant::runtime::Engine::open(&dir).expect("engine");
            let mut rng = Rng::new(2);
            let (train, _) = data.split(0.1, &mut rng);
            let mut pjrt = lcquant::runtime::PjrtBackend::new(engine, "lenet300", train, None, 3)
                .expect("pjrt backend");
            // warm the executable cache
            let _ = pjrt.next_loss_grads();
            let mut popt = FlatNesterov::new(pjrt.layout(), 0.95);
            let s = bench("pjrt L-step (batch from artifact)", 30, || {
                run_sgd(&mut pjrt, &mut popt, 1, 0.05, None)
            });
            println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);
        } else {
            println!("(artifacts not built; skipping PJRT L-step — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the 'pjrt' feature; skipping PJRT L-step)");
}
