//! L-step throughput: minibatch loss+grad+update steps per second on the
//! native backend (and the PJRT backend when artifacts are present),
//! LeNet300 shapes, batch 128. The C step is benchmarked separately
//! (bench_cstep) — the paper's claim "C-step runtime is negligible vs the
//! L step" is checked in bench_e2e.

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov, PenaltyState};
use lcquant::coordinator::{Backend, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::{Mlp, MlpSpec};
#[cfg(feature = "pjrt")]
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

fn main() {
    println!("== bench_lstep ==");
    let mut data = SynthMnist::generate(1_024, 1);
    data.subtract_mean(None);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, data.clone(), None, 128, 1);
    let mut opt = FlatNesterov::new(&backend.weights(), &backend.biases(), 0.95);

    let s = bench("native L-step (batch=128, no penalty)", 30, || {
        run_sgd(&mut backend, &mut opt, 1, 0.05, None)
    });
    println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);

    let w = backend.weights();
    let penalty = PenaltyState {
        wc: w.iter().map(|l| vec![0.0; l.len()]).collect(),
        lambda: w.iter().map(|l| vec![0.0; l.len()]).collect(),
        mu: 0.01,
    };
    let s = bench("native L-step (batch=128, with penalty)", 30, || {
        run_sgd(&mut backend, &mut opt, 1, 0.05, Some(&penalty))
    });
    println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);

    // PJRT backend, if compiled in and artifacts were built
    #[cfg(feature = "pjrt")]
    {
        let dir = lcquant::runtime::Engine::default_dir();
        if lcquant::runtime::Engine::available(&dir) {
            let engine = lcquant::runtime::Engine::open(&dir).expect("engine");
            let mut rng = Rng::new(2);
            let (train, _) = data.split(0.1, &mut rng);
            let mut pjrt = lcquant::runtime::PjrtBackend::new(engine, "lenet300", train, None, 3)
                .expect("pjrt backend");
            // warm the executable cache
            let _ = pjrt.next_loss_grads();
            let mut popt = FlatNesterov::new(&pjrt.weights(), &pjrt.biases(), 0.95);
            let s = bench("pjrt L-step (batch from artifact)", 30, || {
                run_sgd(&mut pjrt, &mut popt, 1, 0.05, None)
            });
            println!("{}  ({:.1} steps/s)", s.report(), 1.0 / s.median_s);
        } else {
            println!("(artifacts not built; skipping PJRT L-step — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the 'pjrt' feature; skipping PJRT L-step)");
}
