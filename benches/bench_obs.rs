//! Observability-plane overhead benchmarks → `BENCH_obs.json`:
//!
//! * raw hot-path costs: one `Histogram::record_ns` (two relaxed
//!   fetch-adds) and one `TraceRing::record` (CAS claim + nine relaxed
//!   stores), in ns/op;
//! * the number that matters: micro-batch server throughput with the
//!   global registry + tracing **enabled vs disabled**
//!   (`obs::set_enabled`), same model, same 8-thread client load — the
//!   instrumentation's end-to-end tax on req/s.

use lcquant::linalg::pool;
use lcquant::nn::MlpSpec;
use lcquant::obs::{self, Histogram, Stage, Trace, TraceRing};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
use lcquant::util::rng::Rng;
use lcquant::util::timer::Timer;
use std::sync::Arc;
use std::time::Duration;

/// Quantize random LeNet300-shaped weights (the bench cares about the
/// serving path's instrumentation cost, not accuracy).
fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

/// ns/op for `n` repetitions of `f`.
fn per_op_ns<F: FnMut(u64)>(n: u64, mut f: F) -> f64 {
    let t = Timer::start();
    for i in 0..n {
        f(i);
    }
    t.elapsed_s() * 1e9 / n as f64
}

/// One instrumented-or-not serve pass: 8 client threads × `per_thread`
/// single-image requests against a fresh server. Returns req/s.
fn serve_pass(registry: &Arc<Registry>, per_thread: usize) -> f64 {
    let server = MicroBatchServer::start(
        Arc::clone(registry),
        ServerConfig { max_batch: 64, max_wait: Duration::from_millis(2), pipeline_depth: 2 },
    );
    let n_threads = 8usize;
    let clients: Vec<_> = (0..n_threads).map(|_| server.client()).collect();
    let t = Timer::start();
    // blocking request drivers: scoped threads, the engine keeps the pool
    pool::run_scoped(n_threads, |th| {
        let client = &clients[th];
        let mut trng = Rng::new(300 + th as u64);
        let mut x = vec![0.0f32; 784];
        for _ in 0..per_thread {
            trng.fill_normal(&mut x, 0.0, 1.0);
            client.infer("binary", x.clone()).expect("infer");
        }
    });
    let elapsed = t.elapsed_s();
    let mut server = server;
    server.stop();
    (n_threads * per_thread) as f64 / elapsed
}

fn main() {
    println!("== bench_obs: observability hot-path + end-to-end overhead ==");

    // ---- raw hot-path costs -------------------------------------------
    let n = 4_000_000u64;
    let hist = Histogram::new();
    let hist_ns = per_op_ns(n, |i| hist.record_ns(i.wrapping_mul(2654435761) & 0xff_ffff));
    std::hint::black_box(hist.snapshot().count());
    println!("histogram record_ns:   {hist_ns:>7.2} ns/op  ({n} ops)");

    let ring = TraceRing::new(1024);
    let mut trace = Trace::from_parts(0, [0; obs::STAGES]);
    let ring_ns = per_op_ns(n, |i| {
        trace.id = i;
        trace.set(Stage::Compute, i & 0xffff);
        ring.record(&trace);
    });
    std::hint::black_box(ring.snapshot().len());
    println!("trace-ring record:     {ring_ns:>7.2} ns/op  ({n} ops, {} dropped)", ring.dropped());

    // ---- end-to-end A/B: instrumented vs not --------------------------
    let model = packed_lenet300("binary", &Scheme::BinaryScale, 11);
    let mut registry = Registry::new();
    registry.insert(model).unwrap();
    let registry = Arc::new(registry);
    let per_thread = 128usize;
    // warm both paths once (pool spawn, gather structures)
    obs::set_enabled(true);
    let _ = serve_pass(&registry, 16);

    // interleave passes so drift (thermal, page cache) hits both arms
    let (mut on_best, mut off_best) = (0.0f64, 0.0f64);
    for _ in 0..3 {
        obs::set_enabled(true);
        on_best = on_best.max(serve_pass(&registry, per_thread));
        obs::set_enabled(false);
        off_best = off_best.max(serve_pass(&registry, per_thread));
    }
    obs::set_enabled(true);
    let overhead_pct = (off_best / on_best - 1.0) * 100.0;
    println!("serve, obs enabled:  {on_best:>8.0} req/s");
    println!("serve, obs disabled: {off_best:>8.0} req/s  (instrumentation tax {overhead_pct:.1}%)");

    let json = format!(
        "{{\n  \"bench\": \"obs\",\n  \"threads\": {},\n  \
         \"histogram_record_ns\": {hist_ns:.2},\n  \"trace_record_ns\": {ring_ns:.2},\n  \
         \"serve_req_per_s_enabled\": {on_best:.0},\n  \
         \"serve_req_per_s_disabled\": {off_best:.0},\n  \
         \"overhead_pct\": {overhead_pct:.2}\n}}\n",
        lcquant::linalg::num_threads(),
    );
    match std::fs::write("BENCH_obs.json", &json) {
        Ok(()) => println!("wrote BENCH_obs.json"),
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }
}
