//! Observability-plane overhead benchmarks → `BENCH_obs.json`:
//!
//! * raw hot-path costs: one `Histogram::record_ns` (two relaxed
//!   fetch-adds) and one `TraceRing::record` (CAS claim + nine relaxed
//!   stores), in ns/op;
//! * the number that matters: micro-batch server throughput with the
//!   global registry + tracing **enabled vs disabled**
//!   (`obs::set_enabled`), same model, same 8-thread client load — the
//!   instrumentation's end-to-end tax on req/s;
//! * the v3 fleet numbers: routed loopback load through a two-replica
//!   `RouterServer` with client trace stamping **on vs off** (the
//!   cross-tier propagation tax rides the same wire bytes + one ring
//!   record per tier), and a `FleetStatsRequest` fan-out cost sweep
//!   over 1/2/4 backends (ms per aggregated snapshot).

use lcquant::linalg::pool;
use lcquant::net::{
    loadgen, FabricConfig, LoadGenConfig, NetClient, NetConfig, NetServer, RouterConfig,
    RouterServer, ShardConfig,
};
use lcquant::nn::MlpSpec;
use lcquant::obs::{self, Histogram, Stage, Trace, TraceRing};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
use lcquant::util::backoff::BackoffCfg;
use lcquant::util::rng::Rng;
use lcquant::util::timer::Timer;
use std::sync::Arc;
use std::time::Duration;

/// Quantize random LeNet300-shaped weights (the bench cares about the
/// serving path's instrumentation cost, not accuracy).
fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

/// ns/op for `n` repetitions of `f`.
fn per_op_ns<F: FnMut(u64)>(n: u64, mut f: F) -> f64 {
    let t = Timer::start();
    for i in 0..n {
        f(i);
    }
    t.elapsed_s() * 1e9 / n as f64
}

/// One instrumented-or-not serve pass: 8 client threads × `per_thread`
/// single-image requests against a fresh server. Returns req/s.
fn serve_pass(registry: &Arc<Registry>, per_thread: usize) -> f64 {
    let server = MicroBatchServer::start(
        Arc::clone(registry),
        ServerConfig { max_batch: 64, max_wait: Duration::from_millis(2), pipeline_depth: 2 },
    );
    let n_threads = 8usize;
    let clients: Vec<_> = (0..n_threads).map(|_| server.client()).collect();
    let t = Timer::start();
    // blocking request drivers: scoped threads, the engine keeps the pool
    pool::run_scoped(n_threads, |th| {
        let client = &clients[th];
        let mut trng = Rng::new(300 + th as u64);
        let mut x = vec![0.0f32; 784];
        for _ in 0..per_thread {
            trng.fill_normal(&mut x, 0.0, 1.0);
            client.infer("binary", x.clone()).expect("infer");
        }
    });
    let elapsed = t.elapsed_s();
    let mut server = server;
    server.stop();
    (n_threads * per_thread) as f64 / elapsed
}

/// Bind one loopback backend over the shared registry.
fn backend(reg: Arc<Registry>) -> NetServer {
    NetServer::start(
        reg,
        ServerConfig { max_batch: 64, max_wait: Duration::from_millis(2), pipeline_depth: 2 },
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 16,
            ..NetConfig::default()
        },
    )
    .expect("bind backend")
}

/// Bind a router over `replicas` (no health probing: the bench wants the
/// steady-state forward path, not probe noise).
fn router(replicas: Vec<String>) -> RouterServer {
    RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 16,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig { models: Vec::new(), replicas }],
            retry_budget: 4,
            deadline: Duration::from_secs(10),
            backoff: BackoffCfg { base: Duration::from_millis(1), cap: Duration::from_millis(10) },
            probe_every: Duration::ZERO,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router")
}

fn main() {
    println!("== bench_obs: observability hot-path + end-to-end overhead ==");

    // ---- raw hot-path costs -------------------------------------------
    let n = 4_000_000u64;
    let hist = Histogram::new();
    let hist_ns = per_op_ns(n, |i| hist.record_ns(i.wrapping_mul(2654435761) & 0xff_ffff));
    std::hint::black_box(hist.snapshot().count());
    println!("histogram record_ns:   {hist_ns:>7.2} ns/op  ({n} ops)");

    let ring = TraceRing::new(1024);
    let mut trace = Trace::from_parts(0, 0, [0; obs::STAGES]);
    let ring_ns = per_op_ns(n, |i| {
        trace.id = i;
        trace.set(Stage::Compute, i & 0xffff);
        ring.record(&trace);
    });
    std::hint::black_box(ring.snapshot().len());
    println!("trace-ring record:     {ring_ns:>7.2} ns/op  ({n} ops, {} dropped)", ring.dropped());

    // ---- end-to-end A/B: instrumented vs not --------------------------
    let model = packed_lenet300("binary", &Scheme::BinaryScale, 11);
    let mut registry = Registry::new();
    registry.insert(model).unwrap();
    let registry = Arc::new(registry);
    let per_thread = 128usize;
    // warm both paths once (pool spawn, gather structures)
    obs::set_enabled(true);
    let _ = serve_pass(&registry, 16);

    // interleave passes so drift (thermal, page cache) hits both arms
    let (mut on_best, mut off_best) = (0.0f64, 0.0f64);
    for _ in 0..3 {
        obs::set_enabled(true);
        on_best = on_best.max(serve_pass(&registry, per_thread));
        obs::set_enabled(false);
        off_best = off_best.max(serve_pass(&registry, per_thread));
    }
    obs::set_enabled(true);
    let overhead_pct = (off_best / on_best - 1.0) * 100.0;
    println!("serve, obs enabled:  {on_best:>8.0} req/s");
    println!("serve, obs disabled: {off_best:>8.0} req/s  (instrumentation tax {overhead_pct:.1}%)");

    // ---- routed A/B: trace stamping on vs off (v3) --------------------
    println!("\n== routed loopback: client trace stamping on vs off ==");
    let b0 = backend(Arc::clone(&registry));
    let b1 = backend(Arc::clone(&registry));
    let mut rt = router(vec![b0.local_addr().to_string(), b1.local_addr().to_string()]);
    let routed_addr = rt.local_addr().to_string();
    let routed_pass = |trace: bool, seed: u64| {
        let mut lg = LoadGenConfig::new(&routed_addr);
        lg.connections = 4;
        lg.requests_per_conn = 128;
        lg.seed = seed;
        lg.trace = trace;
        loadgen::run(&lg).expect("routed loadgen")
    };
    let _ = routed_pass(true, 3); // warm pooled backend connections
    let (mut traced_best, mut plain_best) = (0.0f64, 0.0f64);
    let (mut traced_p99, mut plain_p99) = (f32::MAX, f32::MAX);
    let mut coverage = 1.0f64;
    for round in 0..3u64 {
        let t = routed_pass(true, 100 + round);
        if t.req_per_s() > traced_best {
            traced_best = t.req_per_s();
            coverage = t.trace_coverage();
        }
        traced_p99 = traced_p99.min(t.p99_ms);
        let p = routed_pass(false, 200 + round);
        plain_best = plain_best.max(p.req_per_s());
        plain_p99 = plain_p99.min(p.p99_ms);
    }
    let trace_tax_pct = (plain_best / traced_best - 1.0) * 100.0;
    println!(
        "routed, trace on:  {traced_best:>8.0} req/s  p99 {traced_p99:.2}ms  (coverage {:.0}%)",
        100.0 * coverage
    );
    println!(
        "routed, trace off: {plain_best:>8.0} req/s  p99 {plain_p99:.2}ms  \
         (propagation tax {trace_tax_pct:.1}%)"
    );
    rt.stop();
    let (mut b0, mut b1) = (b0, b1);
    b0.stop();
    b1.stop();

    // ---- fleet-stats fan-out cost sweep -------------------------------
    println!("\n== FleetStatsRequest fan-out: ms per aggregated snapshot ==");
    let mut fanout_rows: Vec<(usize, f64)> = Vec::new();
    for n_backends in [1usize, 2, 4] {
        let mut backends: Vec<NetServer> =
            (0..n_backends).map(|_| backend(Arc::clone(&registry))).collect();
        let mut rt = router(backends.iter().map(|b| b.local_addr().to_string()).collect());
        let mut client =
            NetClient::connect(&rt.local_addr().to_string()).expect("connect router");
        let _ = client.fleet_stats().expect("warm fleet stats");
        let polls = 20u64;
        let ms_per = per_op_ns(polls, |_| {
            std::hint::black_box(client.fleet_stats().expect("fleet stats").len());
        }) / 1e6;
        println!("backends={n_backends}: {ms_per:>7.3} ms/snapshot  ({polls} polls)");
        fanout_rows.push((n_backends, ms_per));
        drop(client);
        rt.stop();
        for b in &mut backends {
            b.stop();
        }
    }
    let fanout_json: Vec<String> = fanout_rows
        .iter()
        .map(|(n, ms)| format!("{{\"backends\": {n}, \"ms_per_snapshot\": {ms:.3}}}"))
        .collect();

    let json = format!(
        "{{\n  \"bench\": \"obs\",\n  \"threads\": {},\n  \
         \"histogram_record_ns\": {hist_ns:.2},\n  \"trace_record_ns\": {ring_ns:.2},\n  \
         \"serve_req_per_s_enabled\": {on_best:.0},\n  \
         \"serve_req_per_s_disabled\": {off_best:.0},\n  \
         \"overhead_pct\": {overhead_pct:.2},\n  \
         \"routed_req_per_s_traced\": {traced_best:.0},\n  \
         \"routed_req_per_s_untraced\": {plain_best:.0},\n  \
         \"trace_tax_pct\": {trace_tax_pct:.2},\n  \
         \"trace_coverage\": {coverage:.3},\n  \
         \"fleet_fanout\": [{}]\n}}\n",
        lcquant::linalg::num_threads(),
        fanout_json.join(", "),
    );
    match std::fs::write("BENCH_obs.json", &json) {
        Ok(()) => println!("wrote BENCH_obs.json"),
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }
}
