//! Serving-path benchmarks, LeNet300 shapes (784-300-100-10):
//!
//! * packed-LUT forward vs dense f32 GEMM forward at batch 1 / 32 / 256,
//!   across the codebook families (binary sign path, adaptive K=4/K=64
//!   grouped path, pow2 shift path) — the §2.1 lookup-vs-multiply claim;
//! * micro-batching server throughput under concurrent single-image load,
//!   at pipeline depth 1 vs 4;
//! * a **multi-client saturation sweep** (1/2/4/8 concurrent batch-256
//!   requests straight into the LUT engine) → `BENCH_serve_pipeline.json`:
//!   under the old single-task pool, concurrent forwards degraded to
//!   inline serial execution the moment one request owned the pool; the
//!   multi-task queue lets their layer-band tasks interleave, so aggregate
//!   throughput must scale past the single-client baseline;
//! * a **loopback LCQ-RPC sweep** (`NetServer` on 127.0.0.1, the loadgen
//!   driving 1/2/4/8 connections, plus pipeline depth 1 vs 4 at 8
//!   connections) → `BENCH_net.json`: what the wire + connection plane
//!   cost on top of the in-process micro-batcher;
//! * the PJRT artifact for comparison when built with `--features pjrt`
//!   and `make artifacts`.

use lcquant::linalg::{pool, Mat};
use lcquant::nn::MlpSpec;
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{
    EngineScratch, LutEngine, MicroBatchServer, PackedModel, Registry, ServerConfig,
};
use lcquant::util::rng::Rng;
use lcquant::util::timer::{bench, Timer};
use std::sync::Arc;
use std::time::Duration;

/// Quantize random LeNet300-shaped weights (no training: the bench cares
/// about FLOPs and memory traffic, not accuracy).
fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn main() {
    println!("== bench_serve: packed-LUT inference vs dense GEMM (LeNet300) ==");
    let variants: Vec<(&str, Scheme)> = vec![
        ("binary", Scheme::BinaryScale),
        ("adaptive-k4", Scheme::AdaptiveCodebook { k: 4 }),
        ("adaptive-k64", Scheme::AdaptiveCodebook { k: 64 }),
        ("pow2-c6", Scheme::PowersOfTwo { c: 6 }),
    ];
    let mut rng = Rng::new(3);
    let models: Vec<PackedModel> = variants
        .iter()
        .enumerate()
        .map(|(i, (name, scheme))| packed_lenet300(name, scheme, 10 + i as u64))
        .collect();

    for batch in [1usize, 32, 256] {
        let mut x = Mat::zeros(batch, 784);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let iters = if batch >= 256 { 12 } else { 30 };

        // dense baseline: same weights expanded to f32, Mlp::forward
        let dense = models[0].to_mlp();
        let sd = bench(&format!("dense f32 GEMM        batch={batch}"), iters, || {
            dense.forward(&x, false, None)
        });
        println!("{}  ({:.0} img/s)", sd.report(), sd.per_sec(batch));

        for model in &models {
            let engine = LutEngine::new(model).unwrap();
            let s = bench(
                &format!("packed-LUT {:<11} batch={batch}", model.name),
                iters,
                || engine.forward(&x).unwrap(),
            );
            println!(
                "{}  ({:.0} img/s, {:.2}x dense time, ×{:.1} on disk)",
                s.report(),
                s.per_sec(batch),
                s.median_s / sd.median_s,
                model.compression_ratio(),
            );
        }
        println!();
    }

    // ---- micro-batching server throughput -----------------------------
    println!("== micro-batch server throughput (binary model, 8 client threads) ==");
    let mut registry = Registry::new();
    registry.insert(models[0].clone()).unwrap();
    let registry = Arc::new(registry);
    let mut server_rows: Vec<(usize, f64, f32, f32, f64)> = Vec::new();
    for (max_batch, max_wait_ms, depth) in
        [(1usize, 0u64, 1usize), (64, 2, 1), (64, 2, 4)]
    {
        let server = MicroBatchServer::start(
            Arc::clone(&registry),
            ServerConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
                pipeline_depth: depth,
            },
        );
        let n_threads = 8usize;
        let per_thread = 128usize;
        let clients: Vec<_> = (0..n_threads).map(|_| server.client()).collect();
        let t = Timer::start();
        // blocking request drivers: scoped threads, not pool parts, so the
        // engine being measured keeps the worker pool to itself
        pool::run_scoped(n_threads, |th| {
            let client = &clients[th];
            let mut trng = Rng::new(100 + th as u64);
            let mut x = vec![0.0f32; 784];
            for _ in 0..per_thread {
                trng.fill_normal(&mut x, 0.0, 1.0);
                client.infer("binary", x.clone()).expect("infer");
            }
        });
        let elapsed = t.elapsed_s();
        let mut server = server;
        server.stop();
        let stats = server.stats();
        let req_s = stats.requests as f64 / elapsed;
        println!(
            "max_batch={max_batch:<3} wait={max_wait_ms}ms depth={depth}: {req_s:>6.0} req/s  \
             p50 {:.2}ms  p99 {:.2}ms  mean batch {:.1}",
            stats.p50_ms,
            stats.p99_ms,
            stats.mean_batch,
        );
        if max_batch == 64 {
            server_rows.push((depth, req_s, stats.p50_ms, stats.p99_ms, stats.mean_batch));
        }
    }

    bench_pipeline_sweep(&models[1], &server_rows);

    bench_net_sweep(&models[0]);

    // ---- PJRT artifact, when available --------------------------------
    run_pjrt_section();
}

/// Loopback TCP sweep: the same micro-batcher behind the LCQ-RPC
/// connection plane, driven by the multi-connection load generator.
/// Writes `BENCH_net.json` (connections × depth → req/s, p50/p99, shed).
fn bench_net_sweep(model: &PackedModel) {
    use lcquant::net::{loadgen, LoadGenConfig, NetConfig, NetServer};
    println!("\n== loopback LCQ-RPC sweep ({}) ==", model.name);
    let mut registry = Registry::new();
    registry.insert(model.clone()).unwrap();
    let registry = Arc::new(registry);
    let per_conn = 128usize;
    let mut rows: Vec<(usize, usize, f64, f32, f32, usize)> = Vec::new();
    for (conns, depth) in [(1usize, 2usize), (2, 2), (4, 2), (8, 2), (8, 1), (8, 4)] {
        let server = NetServer::start(
            Arc::clone(&registry),
            ServerConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                pipeline_depth: depth,
            },
            NetConfig {
                bind_addr: "127.0.0.1:0".to_string(),
                max_connections: 16,
                ..NetConfig::default()
            },
        )
        .expect("bind loopback bench server");
        let mut lg = LoadGenConfig::new(&server.local_addr().to_string());
        lg.connections = conns;
        lg.requests_per_conn = per_conn;
        lg.seed = 7;
        let report = loadgen::run(&lg).expect("loadgen");
        println!(
            "conns={conns} depth={depth}: {:>6.0} req/s  p50 {:.2}ms  p99 {:.2}ms  \
             ({} ok, {} shed)",
            report.req_per_s(),
            report.p50_ms,
            report.p99_ms,
            report.ok,
            report.shed,
        );
        rows.push((conns, depth, report.req_per_s(), report.p50_ms, report.p99_ms, report.shed));
        let mut server = server;
        server.stop();
    }

    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"net\",\n");
    json.push_str(&format!(
        "  \"threads\": {},\n  \"model\": \"{}\",\n  \"requests_per_conn\": {per_conn},\n  \
         \"sweep\": [\n",
        lcquant::linalg::num_threads(),
        model.name
    ));
    for (i, (conns, depth, req_s, p50, p99, shed)) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    {{\"connections\": {conns}, \"pipeline_depth\": {depth}, \
             \"req_per_s\": {req_s:.0}, \"p50_ms\": {p50:.3}, \"p99_ms\": {p99:.3}, \
             \"shed\": {shed}}}{comma}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("wrote BENCH_net.json"),
        Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
    }
}

/// 1/2/4/8 concurrent batch-256 requests straight into one engine: the
/// multi-task-pool saturation proof, written to `BENCH_serve_pipeline.json`
/// together with the depth-1-vs-4 server numbers.
fn bench_pipeline_sweep(model: &PackedModel, server_rows: &[(usize, f64, f32, f32, f64)]) {
    println!("\n== multi-client saturation sweep ({}, batch 256) ==", model.name);
    let engine = LutEngine::new(model).unwrap();
    let batch = 256usize;
    let reps = 8usize;
    let mut rng = Rng::new(17);
    let mut x = Mat::zeros(batch, 784);
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    // warm: pool spawn + gather structures touched
    let _ = engine.forward(&x).unwrap();
    let mut rows: Vec<(usize, f64)> = Vec::new();
    for clients in [1usize, 2, 4, 8] {
        let t = Timer::start();
        // concurrent *requests* are blocking drivers (each waits for its
        // own forward), so they fan out on scoped threads; every forward's
        // layer bands land as tasks on the multi-task worker pool
        pool::run_scoped(clients, |_| {
            let mut scratch = EngineScratch::new();
            for _ in 0..reps {
                let out = engine.forward_into(&x, &mut scratch).unwrap();
                std::hint::black_box(out.data.len());
            }
        });
        let elapsed = t.elapsed_s();
        let imgs_s = (clients * reps * batch) as f64 / elapsed;
        rows.push((clients, imgs_s));
        let scaling = imgs_s / rows[0].1;
        println!("clients={clients}: {imgs_s:>9.0} img/s aggregate  ({scaling:.2}x vs 1 client)");
    }

    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"serve_pipeline\",\n");
    json.push_str(&format!(
        "  \"threads\": {},\n  \"engine_sweep\": {{\n    \"model\": \"{}\",\n    \
         \"batch\": {batch},\n    \"reps_per_client\": {reps},\n    \"clients\": [\n",
        lcquant::linalg::num_threads(),
        model.name
    ));
    for (i, (clients, imgs_s)) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        json.push_str(&format!(
            "      {{\"clients\": {clients}, \"imgs_per_s\": {imgs_s:.0}, \
             \"scaling_vs_1\": {:.3}}}{comma}\n",
            imgs_s / rows[0].1
        ));
    }
    json.push_str("    ]\n  },\n  \"server_sweep\": [\n");
    for (i, (depth, req_s, p50, p99, mean_batch)) in server_rows.iter().enumerate() {
        let comma = if i + 1 == server_rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    {{\"pipeline_depth\": {depth}, \"req_per_s\": {req_s:.0}, \
             \"p50_ms\": {p50:.3}, \"p99_ms\": {p99:.3}, \"mean_batch\": {mean_batch:.2}}}{comma}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve_pipeline.json", &json) {
        Ok(()) => println!("wrote BENCH_serve_pipeline.json"),
        Err(e) => eprintln!("could not write BENCH_serve_pipeline.json: {e}"),
    }
}

fn run_pjrt_section() {
    #[cfg(feature = "pjrt")]
    {
        let dir = lcquant::runtime::Engine::default_dir();
        if lcquant::runtime::Engine::available(&dir) {
            if let Err(e) = bench_pjrt(&dir) {
                println!("(pjrt bench failed: {e})");
            }
        } else {
            println!("(artifacts not built; skipping PJRT comparison — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the 'pjrt' feature; skipping PJRT comparison)");
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(dir: &std::path::Path) -> anyhow::Result<()> {
    use anyhow::anyhow;
    use lcquant::runtime::{literal_f32, literal_i32, Engine};
    let mut engine = Engine::open(dir)?;
    let spec_art = engine
        .manifest
        .artifacts
        .get("lenet300_quantized_fwd")
        .ok_or_else(|| anyhow!("artifact lenet300_quantized_fwd missing"))?
        .clone();
    let batch = spec_art.meta.get("batch").copied().unwrap_or(128.0) as usize;
    let k = spec_art.meta.get("k").copied().unwrap_or(2.0) as usize;
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(5);
    let mut x = vec![0.0f32; batch * 784];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut inputs: Vec<xla::Literal> = vec![literal_f32(&x, &[batch, 784])?];
    let model = packed_lenet300("pjrt", &Scheme::AdaptiveCodebook { k }, 77);
    for (l, layer) in model.layers.iter().enumerate() {
        let ids: Vec<i32> = layer.unpack_assignments().iter().map(|&a| a as i32).collect();
        inputs.push(literal_i32(&ids, &[spec.sizes[l], spec.sizes[l + 1]])?);
        inputs.push(literal_f32(&layer.codebook, &[k])?);
        inputs.push(literal_f32(&layer.bias, &[layer.bias.len()])?);
    }
    engine.compile("lenet300_quantized_fwd")?;
    let s = bench(&format!("pjrt artifact          batch={batch}"), 20, || {
        engine.execute("lenet300_quantized_fwd", &inputs).expect("execute")
    });
    println!("{}  ({:.0} img/s)", s.report(), s.per_sec(batch));
    Ok(())
}
