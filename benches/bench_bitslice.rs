//! Bit-sliced serving tier vs the LUT gather tier, LeNet300 shapes
//! (784-300-100-10), plus the cold-load cost of the zero-copy `.lcq`
//! path:
//!
//! * per-scheme forward sweep at batch 1 / 32 / 256: the same
//!   `PackedModel` served by `EngineMode::Lut` (grouped per-centroid
//!   gathers) and `EngineMode::BitSliced` (popcount / two-plane /
//!   K-accumulator / exponent-shift kernels straight on the packed `u64`
//!   plane words) — the tentpole claim is that the bit-sliced tier wins
//!   at low K by replacing per-weight index reads with word-parallel
//!   plane arithmetic;
//! * cold model load, eager (`PackedModel::load`: read + verify every
//!   section) vs zero-copy (`PackedModel::load_mmap`: map + verify the
//!   header only, sections lazily on first touch);
//!
//! → `BENCH_bitslice.json`. Run via `make bench-bitslice`.

use lcquant::linalg::Mat;
use lcquant::nn::MlpSpec;
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{EngineMode, EngineScratch, LutEngine, PackedModel};
use lcquant::util::rng::Rng;
use lcquant::util::timer::bench;

fn packed_lenet300(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec::lenet300();
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.1)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.05)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn main() {
    println!("== bench_bitslice: bit-sliced tier vs LUT gather tier (LeNet300) ==");
    let variants: Vec<(&str, Scheme)> = vec![
        ("binary", Scheme::BinaryScale),
        ("ternary", Scheme::TernaryScale),
        ("pow2-c6", Scheme::PowersOfTwo { c: 6 }),
        ("adaptive-k4", Scheme::AdaptiveCodebook { k: 4 }),
    ];
    let models: Vec<PackedModel> = variants
        .iter()
        .enumerate()
        .map(|(i, (name, scheme))| packed_lenet300(name, scheme, 20 + i as u64))
        .collect();

    let mut rows = String::new();
    let mut rng = Rng::new(9);
    for batch in [1usize, 32, 256] {
        let mut x = Mat::zeros(batch, 784);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let iters = if batch >= 256 { 12 } else { 40 };
        for model in &models {
            let mut pair = Vec::new();
            for mode in [EngineMode::Lut, EngineMode::BitSliced] {
                let engine = LutEngine::with_mode(model, mode).unwrap();
                let paths = engine.layer_paths().join(",");
                let mut scratch = EngineScratch::new();
                let _ = engine.forward_into(&x, &mut scratch).unwrap(); // warm
                let s = bench(
                    &format!("{:<12} {:<9} batch={batch}", model.name, mode.name()),
                    iters,
                    || {
                        let y = engine.forward_into(&x, &mut scratch).unwrap();
                        y.data[0]
                    },
                );
                println!("{}  ({:.0} img/s)  [{paths}]", s.report(), s.per_sec(batch));
                pair.push(s.median_s);
            }
            let speedup = pair[0] / pair[1];
            println!("    bit-sliced speedup over LUT: {speedup:.2}x");
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            rows.push_str(&format!(
                "    {{\"model\": \"{}\", \"batch\": {batch}, \"lut_median_s\": {:.6e}, \
                 \"bitsliced_median_s\": {:.6e}, \"speedup\": {:.3}}}",
                model.name, pair[0], pair[1], speedup
            ));
        }
    }

    // cold load: eager (read + verify every section) vs zero-copy mmap
    // (header only; section checksums deferred to first touch)
    println!("\n== cold .lcq load: eager vs mmap ==");
    let dir = std::env::temp_dir().join("lcquant_bench_bitslice");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("adaptive-k4.lcq");
    models[3].save(&path).unwrap();
    let se = bench("eager load (read+verify) ", 40, || PackedModel::load(&path).unwrap().layers.len());
    println!("{}", se.report());
    let sm = bench("mmap load (header only)  ", 40, || {
        PackedModel::load_mmap(&path).unwrap().layers.len()
    });
    println!("{}", sm.report());
    println!("    mmap cold-load speedup: {:.2}x", se.median_s / sm.median_s);
    let _ = std::fs::remove_dir_all(&dir);

    let json = format!(
        "{{\n  \"bench\": \"bitslice\",\n  \"threads\": {},\n  \"forward_sweep\": [\n{rows}\n  ],\n  \
         \"cold_load\": {{\"eager_median_s\": {:.6e}, \"mmap_median_s\": {:.6e}, \"speedup\": {:.3}}}\n}}\n",
        lcquant::linalg::num_threads(),
        se.median_s,
        sm.median_s,
        se.median_s / sm.median_s
    );
    match std::fs::write("BENCH_bitslice.json", &json) {
        Ok(()) => println!("wrote BENCH_bitslice.json"),
        Err(e) => eprintln!("could not write BENCH_bitslice.json: {e}"),
    }
}
